"""Serving-path tests: KV-cache decode must match full-forward greedy."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch.mesh import make_mesh
from repro.models import api as model_api, transformer
from repro.parallel.sharding import DEFAULT_RULES, axis_rules
from repro.serve import ServeEngine


def _no_drop(cfg):
    """Capacity drops make cached vs uncached runs diverge (expected for
    capacity MoE); equivalence tests use a no-drop capacity factor."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("arch", ["olmo-1b", "minicpm-2b", "xlstm-125m",
                                  "jamba-v0.1-52b", "olmoe-1b-7b"])
def test_decode_matches_forward(arch):
    cfg = _no_drop(get_reduced(arch))
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(cfg, mesh, batch=2, prompt_len=16, max_seq=48, seed=0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    toks, stats = eng.generate(prompts, n_tokens=6)

    with axis_rules(DEFAULT_RULES, mesh):
        params, _ = model_api.init_model(jax.random.key(0), cfg)
        seq = jnp.asarray(prompts)
        for _ in range(6):
            logits, _, _ = transformer.forward(params, cfg, seq, remat=False)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)
            seq = jnp.concatenate([seq, nxt.astype(jnp.int32)[:, None]], 1)
    oracle = np.asarray(seq[:, 16:])
    np.testing.assert_array_equal(toks, oracle)
    assert stats.tokens_generated == 12


def test_whisper_generate_smoke():
    cfg = get_reduced("whisper-base")
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(cfg, mesh, batch=2, prompt_len=16, max_seq=40, seed=0)
    rng = np.random.default_rng(0)
    frames = rng.standard_normal((2, 16, cfg.d_model)).astype(np.float32) * 0.02
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    toks, _ = eng.generate(prompts, n_tokens=5, frames=frames)
    assert toks.shape == (2, 5)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_prompt_exceeding_max_seq_rejected_up_front():
    """Regression: prompt_len > max_seq used to surface as a negative-pad
    crash deep inside jnp.pad when growing prefill caches; now both engine
    construction and generate() validate the window with clear errors."""
    cfg = get_reduced("olmo-1b")
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match=r"prompt_len 64 exceeds max_seq 32"):
        ServeEngine(cfg, mesh, batch=2, prompt_len=64, max_seq=32, seed=0)

    eng = ServeEngine(cfg, mesh, batch=2, prompt_len=8, max_seq=16, seed=0)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    with pytest.raises(ValueError, match=r"exceeds max_seq 16"):
        eng.generate(prompts, n_tokens=9)
    toks, _ = eng.generate(prompts, n_tokens=8)   # exactly fills the window
    assert toks.shape == (2, 8)


def test_sampler():
    from repro.serve import sampler

    logits = jnp.asarray([[0.0, 5.0, 1.0], [9.0, 0.0, 0.0]])
    np.testing.assert_array_equal(np.asarray(sampler.greedy(logits)), [1, 0])
    # temperature 0 == greedy
    s = sampler.sample(logits, jax.random.key(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(s), [1, 0])
    # top-k=1 == greedy regardless of temperature
    s = sampler.sample(logits, jax.random.key(0), temperature=5.0, top_k=1)
    np.testing.assert_array_equal(np.asarray(s), [1, 0])


def test_padded_vocab_never_sampled():
    """Pad logits are masked to -inf: argmax can't land past vocab_size."""
    cfg = get_reduced("olmo-1b", vocab_size=500)   # padded to 512
    assert cfg.padded_vocab == 512
    params, _ = model_api.init_model(jax.random.key(0), cfg)
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 500, (2, 8)),
                         jnp.int32)
    logits, _, _ = transformer.forward(params, cfg, tokens, remat=False)
    assert logits.shape[-1] == 512
    assert int(jnp.max(jnp.argmax(logits, -1))) < 500
