"""Property tests (hypothesis) for the INIT-phase metadata math."""

import numpy as np

from _hypothesis_compat import given, strategies as st

from repro.core import breakeven, metadata as md


counts_matrices = st.integers(2, 10).flatmap(
    lambda p: st.lists(
        st.lists(st.integers(0, 50), min_size=p, max_size=p),
        min_size=p, max_size=p).map(np.array))


@given(counts_matrices)
def test_conservation(counts):
    """Total sent == total received; per-pair counts transpose exactly."""
    rc = md.recv_counts(counts)
    assert rc.sum() == counts.sum()
    np.testing.assert_array_equal(rc.T, counts)


@given(counts_matrices)
def test_displacements_monotone_and_tight(counts):
    d = md.displacements(counts)
    p = counts.shape[0]
    for i in range(p):
        assert d[i, 0] == 0
        np.testing.assert_array_equal(np.diff(d[i]), counts[i, :-1])
        assert d[i, -1] + counts[i, -1] == counts[i].sum()


@given(counts_matrices)
def test_put_displacements_land_inside_window(counts):
    """put_displs[i, j] + count must fit rank j's receive window, and the
    target regions of all senders must tile it without overlap."""
    put = md.put_displacements(counts)
    rc = md.recv_counts(counts)
    p = counts.shape[0]
    for j in range(p):
        total = rc[j].sum()
        spans = sorted((put[i, j], put[i, j] + counts[i, j]) for i in range(p))
        pos = 0
        for lo, hi in spans:
            assert lo == pos and hi <= total
            pos = hi
        assert pos == total


@given(counts_matrices)
def test_capacity_covers_all_pairs(counts):
    cap = md.global_capacity(counts)
    assert cap >= counts.max()
    assert cap % md.TILE_ROWS == 0
    rcaps = md.ring_round_capacities(counts)
    p = counts.shape[0]
    for r in range(1, p):
        diag = counts[np.arange(p), (np.arange(p) + r) % p]
        assert rcaps[r] >= diag.max()
        assert rcaps[r] <= cap  # persistent plans never exceed the fence cap


@given(counts_matrices)
def test_pack_unpack_index_maps_roundtrip(counts):
    """Routing through pack map -> bucket transpose -> unpack map is exactly
    the alltoallv permutation (numpy simulation of the full pipeline)."""
    p = counts.shape[0]
    cap = md.global_capacity(counts)
    sd = md.displacements(counts)
    rc = md.recv_counts(counts)
    rd = md.displacements(rc)
    send_rows = max(md.max_total_send(counts), 1)
    recv_rows = max(md.max_total_recv(counts), 1)

    data = [np.arange(send_rows) + 1000 * i for i in range(p)]
    packed = np.zeros((p, p * cap))
    for i in range(p):
        src, valid = md.pack_index_map(counts[i], sd[i], cap)
        packed[i] = np.where(valid, data[i][src], 0)
    buckets = np.zeros_like(packed)
    for i in range(p):
        for j in range(p):
            buckets[j, i * cap:(i + 1) * cap] = packed[i, j * cap:(j + 1) * cap]
    for j in range(p):
        src, valid = md.unpack_index_map(rc[j], rd[j], cap, recv_rows)
        out = np.where(valid, buckets[j][src], 0)
        # element-wise: rows from sender i carry values 1000*i + local_row
        for i in range(p):
            n = counts[i, j]
            if n:
                seg = out[rd[j, i]: rd[j, i] + n]
                np.testing.assert_array_equal(
                    seg, data[i][sd[i, j]: sd[i, j] + n])


@given(st.floats(1e-6, 10), st.floats(1e-6, 10), st.floats(1e-6, 10))
def test_breakeven_formula(t_init, t_mpi, t_persist):
    n = breakeven.n_breakeven(t_init, t_mpi, t_persist)
    if t_mpi <= t_persist:
        assert n == float("inf")
    else:
        # n is the smallest integer where persistence wins
        assert t_init + n * t_persist <= n * t_mpi + 1e-9
        if n > 1:
            m = n - 1
            assert t_init + m * t_persist >= m * t_mpi - 1e-9


def test_signature_identity():
    c = np.array([[1, 2], [3, 4]])
    s1 = md.PatternSignature.build(c, (4,), "float32", "fence", ("x",), 16)
    s2 = md.PatternSignature.build(c.copy(), (4,), "float32", "fence", ("x",), 16)
    s3 = md.PatternSignature.build(c + 1, (4,), "float32", "fence", ("x",), 16)
    assert s1 == s2 and s1 != s3
    assert s1.total_recv_bytes == c.sum() * 16
