"""Resilience runtime: straggler/skew detection, recovery replay, chaos
injection, and elastic count resharding (single-device; the end-to-end
flows run as dist cases replan_hot_swap / elastic_resume / chaos_recovery)."""

import numpy as np
import pytest

from repro.core._exec_stats import EpochRing, ExecTelemetry
from repro.runtime import chaos as chaos_mod
from repro.runtime import fault as fault_mod
from repro.runtime import replan as replan_mod
from repro.runtime.straggler import PlanSkewMonitor, StragglerDetector


# --- StragglerDetector ------------------------------------------------------

def test_stop_without_start_returns_none():
    det = StragglerDetector()
    assert det.stop(step=0) is None
    assert det.count == 0 and det.ema is None and det.last_step is None


def _feed(det, durations, monkeypatch):
    """Drive the detector with scripted step durations via a fake clock."""
    from repro.runtime import straggler
    clock = {"t": 0.0}
    monkeypatch.setattr(straggler.time, "perf_counter", lambda: clock["t"])
    out = []
    for i, dt in enumerate(durations):
        det.start()
        clock["t"] += dt
        out.append(det.stop(i))
    return out


def test_ema_flags_slow_step_after_warmup(monkeypatch):
    det = StragglerDetector(threshold=2.0, ema_alpha=0.5, warmup_steps=2)
    reports = _feed(det, [0.1, 0.1, 0.1, 0.1, 0.5, 0.1], monkeypatch)
    assert [r is not None for r in reports] == [False] * 4 + [True, False]
    rep = reports[4]
    assert rep.step == 4 and rep.ratio == pytest.approx(5.0, rel=0.01)
    # The flagged step entered the EMA at alpha/4, not alpha: the average
    # must not have jumped toward the outlier.
    assert det.ema < 0.2


def test_checkpoint_early_windows_by_step_recency(monkeypatch):
    det = StragglerDetector(threshold=2.0, ema_alpha=0.1, warmup_steps=2,
                            window_steps=5)
    # Two slow steps far apart: each flags, but never 2 within the window.
    fast, slow = [0.1] * 20, 0.5
    durations = fast[:5] + [slow] + fast[:10] + [slow]
    _feed(det, durations, monkeypatch)
    assert len(det.flagged) == 2
    assert not det.should_checkpoint_early()
    # ...now two slow steps close together => degrading fleet.
    det2 = StragglerDetector(threshold=2.0, ema_alpha=0.1, warmup_steps=2,
                             window_steps=5)
    _feed(det2, fast[:5] + [slow, 0.1, slow], monkeypatch)
    assert len(det2.flagged) == 2
    assert det2.should_checkpoint_early()


# --- RetryPolicy / classify / recovery replay -------------------------------

def test_retry_policy_decays_on_sustained_progress():
    pol = fault_mod.RetryPolicy(max_restarts=3, backoff_seconds=0.0,
                                decay_after=3)
    pol.record_failure(5, RuntimeError("x"))
    pol.record_failure(9, RuntimeError("y"))
    assert pol.restarts == 2
    for _ in range(3):
        pol.record_success()
    assert pol.restarts == 1          # one restart forgiven
    pol.record_success()              # streak restarts after a decay
    assert pol.restarts == 1
    for _ in range(2):
        pol.record_success()
    assert pol.restarts == 0
    for _ in range(10):
        pol.record_success()          # never decays below zero
    assert pol.restarts == 0


def test_retry_policy_failure_resets_streak():
    pol = fault_mod.RetryPolicy(max_restarts=5, backoff_seconds=0.0,
                                decay_after=3)
    pol.record_failure(1, RuntimeError("a"))
    pol.record_success()
    pol.record_success()
    pol.record_failure(4, RuntimeError("b"))   # streak back to 0
    pol.record_success()
    pol.record_success()
    assert pol.restarts == 2          # 2 clean steps < decay_after
    pol.record_success()
    assert pol.restarts == 1


def test_retry_policy_exhaustion_raises():
    pol = fault_mod.RetryPolicy(max_restarts=1, backoff_seconds=0.0)
    pol.record_failure(0, RuntimeError("a"))
    with pytest.raises(fault_mod.FaultError):
        pol.record_failure(1, RuntimeError("b"))


def test_classify_failure():
    assert fault_mod.classify_failure(RuntimeError("oops")) == "transient"
    assert fault_mod.classify_failure(
        chaos_mod.ChaosError("chaos: injected step fault at step 4")) \
        == "transient"
    assert fault_mod.classify_failure(
        chaos_mod.ChaosError("chaos: device lost during step 8")) \
        == "device_loss"
    assert fault_mod.classify_failure(
        chaos_mod.ChaosError("chaos: window allocation failed")) \
        == "device_loss"
    err = type("XlaRuntimeError", (RuntimeError,), {})("anything")
    assert fault_mod.classify_failure(err) == "device_loss"


def test_run_with_recovery_replays_and_rebuilds():
    ran, recoveries, rebuilds = [], [], []
    fired = set()

    def run_step(step):
        if step == 3 and "t" not in fired:
            fired.add("t")
            raise RuntimeError("flaky step")
        if step == 6 and "d" not in fired:
            fired.add("d")
            raise RuntimeError("device dead")
        ran.append(step)
        return {"step": step}

    def restore():
        return (max(ran) + 1) if ran else 0

    final = fault_mod.run_with_recovery(
        run_step, restore=restore, start_step=0, n_steps=9,
        policy=fault_mod.RetryPolicy(max_restarts=3, backoff_seconds=0.0),
        rebuild_plans=lambda err: rebuilds.append(str(err)),
        on_recovery=lambda s, e, k: recoveries.append((s, k)))
    assert final == 9
    assert ran == list(range(9))      # replay is exact: no step skipped/duped
    assert recoveries == [(3, "transient"), (6, "device_loss")]
    # Plans rebuilt ONLY for the device-loss-class failure.
    assert rebuilds == ["device dead"]


# --- chaos injection --------------------------------------------------------

def test_chaos_same_seed_same_schedule():
    def schedule(seed, n=60):
        inj = chaos_mod.ChaosInjector(seed=seed, window_fail_rate=0.3)
        out = []
        for _ in range(n):
            try:
                inj.maybe_fail_window()
                out.append(False)
            except chaos_mod.ChaosError:
                out.append(True)
        return out

    a, b, c = schedule(7), schedule(7), schedule(8)
    assert a == b                     # identical replay
    assert a != c                     # a different seed is a different world
    assert any(a) and not all(a)


def test_chaos_step_faults_fire_once_stalls_every_visit():
    inj = chaos_mod.ChaosInjector(seed=0, fail_steps=(4,),
                                  device_loss_steps=(8,),
                                  stall_steps=(2,), stall_seconds=0.001)
    with pytest.raises(chaos_mod.ChaosError):
        inj.step_hook(4)
    inj.step_hook(4)                  # recovery replay makes progress
    with pytest.raises(chaos_mod.ChaosError):
        inj.step_hook(8)
    inj.step_hook(8)
    inj.step_hook(2)
    inj.step_hook(2)                  # a degraded host is slow on replay too
    assert inj.injected == {"window": 0, "poison": 0, "stall": 2,
                            "step": 1, "device": 1, "rank_slow": 0}


def test_chaos_parse_spec():
    inj = chaos_mod.ChaosInjector.parse(
        "seed=7,window_fail=0.25,fail_step=4+9,device_loss_step=11,"
        "stall_steps=3-5,stall_seconds=0.1")
    assert inj.seed == 7 and inj.window_fail_rate == 0.25
    assert inj.fail_steps == {4, 9}
    assert inj.device_loss_steps == {11}
    assert inj.stall_steps == {3, 4, 5} and inj.stall_seconds == 0.1
    with pytest.raises(ValueError):
        chaos_mod.ChaosInjector.parse("frobnicate=1")
    with pytest.raises(ValueError):
        chaos_mod.ChaosInjector.parse("seed")


def test_poison_store_reads_as_miss_not_crash(tmp_path):
    import jax.numpy as jnp

    from repro.core.autotune import decision_signature
    from repro.core.plan import AlltoallvSpec
    from repro.launch.mesh import make_mesh
    from repro.planstore import PlanStore

    mesh = make_mesh((1,), ("x",))
    spec = AlltoallvSpec(np.array([[3]]), (4,), jnp.float32, ("x",),
                         variant="lock")
    sig = decision_signature(spec, mesh)
    store = PlanStore(str(tmp_path))
    store.put_auto(sig, {"variant": "lock", "codec": "identity"})
    assert store.get_auto(sig)["variant"] == "lock"

    inj = chaos_mod.ChaosInjector(seed=1)
    assert inj.poison_store(store) >= 1
    assert inj.injected["poison"] >= 1
    assert store.get_auto(sig) is None      # corruption degrades to a miss
    assert store.invalid >= 1 or store.errors >= 1


# --- EpochRing / PlanSkewMonitor --------------------------------------------

def test_epoch_ring_wraparound_and_clamping():
    ring = EpochRing(capacity=4)
    assert ring.window(0, 10).size == 0 and ring.last(3).size == 0
    for i in range(10):
        ring.record(float(i))
    assert ring.count == 10
    np.testing.assert_array_equal(ring.last(2), [8.0, 9.0])
    np.testing.assert_array_equal(ring.window(6, 10), [6.0, 7.0, 8.0, 9.0])
    assert ring.window(0, 4).size == 0          # fully evicted
    np.testing.assert_array_equal(ring.window(5, 8), [6.0, 7.0])  # clamped
    np.testing.assert_array_equal(ring.window(8, 99), [8.0, 9.0])


def test_skew_monitor_sustained_not_spike():
    tel = ExecTelemetry()
    ring = tel.ring("digest-a")
    mon = PlanSkewMonitor(ring, threshold=1.5, window=2, sustain=2, warmup=4)
    for _ in range(4):
        ring.record(0.010)
    assert mon.observe() is None                # baseline only
    ring.record(0.100)
    ring.record(0.100)
    assert mon.observe() is None                # 1 hot window: a spike
    ring.record(0.010)
    ring.record(0.010)
    assert mon.observe() is None                # cool window resets the run
    for _ in range(4):
        ring.record(0.100)
    rep = mon.observe()                         # 2 consecutive hot windows
    assert rep is not None and rep.windows_hot == 2
    assert rep.ratio == pytest.approx(10.0, rel=0.05)
    assert rep.baseline == pytest.approx(0.010, rel=0.01)


def test_skew_monitor_reset_reanchors_baseline():
    tel = ExecTelemetry()
    ring = tel.ring("digest-b")
    mon = PlanSkewMonitor(ring, threshold=1.5, window=2, sustain=1, warmup=2)
    for _ in range(2):
        ring.record(0.010)
    for _ in range(2):
        ring.record(0.100)
    assert mon.observe() is not None
    mon.reset()
    # Post-reset the baseline is the NEW normal (0.1s), not the stale one:
    # the same level that just triggered must no longer count as skew.
    for _ in range(4):
        ring.record(0.100)
    assert mon.observe() is None
    assert mon.baseline == pytest.approx(0.100, rel=0.01)


def test_skew_monitor_attribution_to_compute():
    tel = ExecTelemetry()
    plan_ring, compute_ring = tel.ring("plan"), tel.ring("compute")
    mon = PlanSkewMonitor(plan_ring, threshold=1.5, window=2, sustain=1,
                          warmup=2, compute_ring=compute_ring,
                          attribution=1.0)
    for _ in range(2):
        plan_ring.record(0.010)
        compute_ring.record(0.050)
    for _ in range(2):
        plan_ring.record(0.100)      # plan 10x...
        compute_ring.record(0.750)   # ...but compute 15x: whole host is slow
    assert mon.observe() is None     # not the plan's fault — no re-plan
    plan2, comp2 = tel.ring("plan2"), tel.ring("compute2")
    mon2 = PlanSkewMonitor(plan2, threshold=1.5, window=2, sustain=1,
                           warmup=2, compute_ring=comp2, attribution=1.0)
    for _ in range(2):
        plan2.record(0.010)
        comp2.record(0.050)
    for _ in range(2):
        plan2.record(0.100)          # plan 10x, compute flat: blame the plan
        comp2.record(0.050)
    assert mon2.observe() is not None


# --- replan: degrade-to-fence + reshard_counts ------------------------------

class _StubPlan:
    def __init__(self, spec, digest):
        self.spec = spec
        self.signature = type("Sig", (), {"digest": digest})()
        self.auto_choice = None
        self.freed = False

    def free(self):
        self.freed = True


class _StubCache:
    """PlanCache stand-in: hands out stub plans keyed by spec.variant."""

    def __init__(self):
        self.auto_choices = {}
        self.built = []

    def get(self, spec, mesh, store=None):
        self.built.append(spec.variant)
        return _StubPlan(spec, f"digest-{spec.variant}")


def test_replan_degrades_to_fence_when_autotuner_faults(monkeypatch):
    import jax.numpy as jnp

    from repro.core.plan import AlltoallvSpec
    from repro.launch.mesh import make_mesh

    def boom(*a, **k):
        raise RuntimeError("autotuner exploded")

    monkeypatch.setattr(replan_mod, "autotune_variant", boom)
    mesh = make_mesh((1,), ("x",))
    spec = AlltoallvSpec(np.array([[3]]), (4,), jnp.float32, ("x",),
                         variant="lock")
    old = _StubPlan(spec, "digest-old")
    cache = _StubCache()
    mgr = replan_mod.ReplanManager(old, mesh, cache, background=False)
    mgr.trigger("unit")
    assert mgr.observe()                    # degraded plan installs
    assert mgr.replans_completed == 1
    new = mgr.plan
    assert new.spec.variant == "fence" and old.freed
    choice = new.auto_choice
    assert choice["variant"] == "fence" and "degraded" in choice
    assert choice["replan"]["kind"] == "unit"
    assert list(cache.auto_choices.values()) == [choice]
    ev = mgr.events[-1]
    assert ev["event"] == "swap" and ev["variant_to"] == "fence"


def test_reshard_counts_shrink_grow_conserve():
    rng = np.random.default_rng(0)
    c = rng.integers(0, 9, size=(8, 8))
    down = replan_mod.reshard_counts(c, 4)
    assert down.shape == (4, 4) and down.sum() == c.sum()
    # Block sums exactly: new rank r is old ranks {2r, 2r+1}.
    np.testing.assert_array_equal(
        down, c.reshape(4, 2, 4, 2).sum(axis=(1, 3)))
    up = replan_mod.reshard_counts(c, 16)
    assert up.shape == (16, 16) and up.sum() == c.sum()
    # The split is a partition of each old cell over its successor block.
    np.testing.assert_array_equal(
        up.reshape(8, 2, 8, 2).sum(axis=(1, 3)), c)
    np.testing.assert_array_equal(replan_mod.reshard_counts(c, 8), c)
    with pytest.raises(ValueError):
        replan_mod.reshard_counts(c, 3)     # coprime: no principled split
    with pytest.raises(ValueError):
        replan_mod.reshard_counts(c[0], 4)  # not square


# --- leader election: cost model + graceful-degradation ladder ---------------

class _TrackingCache(_StubCache):
    """_StubCache that also keeps the plan objects it handed out (so tests
    can check free()) and tags digests to keep the global telemetry rings
    of different tests from aliasing."""

    def __init__(self, tag=""):
        super().__init__()
        self.plans = []
        self.tag = tag

    def get(self, spec, mesh, store=None):
        self.built.append(spec.variant)
        p = _StubPlan(spec, f"digest{self.tag}-{spec.variant}")
        self.plans.append(p)
        return p


def _hier_stub_plan(digest, p_outer=2, p_inner=4):
    """A fence_hierarchy plan stand-in with the attributes rung 0 reads."""
    import jax.numpy as jnp

    from repro.core import metadata as md
    from repro.core.plan import AlltoallvSpec

    p = p_outer * p_inner
    counts = np.ones((p, p), np.int64)
    spec = AlltoallvSpec(counts, (4,), jnp.float32, ("o", "i"),
                         variant="fence_hierarchy")
    plan = _StubPlan(spec, digest)
    plan.p, plan.p_outer, plan.p_inner = p, p_outer, p_inner
    plan.send_counts = counts
    plan.hier_schedule = type("HS", (), {
        "leader_perm": md.normalize_leader_perm(None, p_outer, p_inner)})()
    return plan


def test_role_carry_dense_concentrates_on_role_zero():
    from repro.runtime import leader as leader_mod

    # (2, 4): one macro round, offsets q+1 — only q=0 reaches the other
    # group (d=1 < p_outer); roles 1..3 are carry-free slack.
    carry = leader_mod.role_carry(np.ones((8, 8), np.int64), 2, 4)
    assert carry.shape == (2, 4)
    # role 0 of each group sends its group's 16 cross rows and receives
    # the other group's 16.
    np.testing.assert_array_equal(carry[:, 0], [32, 32])
    np.testing.assert_array_equal(carry[:, 1:], np.zeros((2, 3), np.int64))


def test_choose_leader_perm_identity_under_uniform_health():
    from repro.runtime import leader as leader_mod

    counts = np.ones((8, 8), np.int64)
    assert leader_mod.choose_leader_perm(counts, 2, 4) \
        == ((0, 1, 2, 3), (0, 1, 2, 3))
    # ...and with an explicit all-ones health vector.
    assert leader_mod.choose_leader_perm(counts, 2, 4, np.ones(8)) \
        == ((0, 1, 2, 3), (0, 1, 2, 3))


def test_choose_leader_perm_demotes_slow_or_excluded_rank():
    from repro.runtime import leader as leader_mod

    counts = np.ones((8, 8), np.int64)
    health = np.ones(8)
    health[0] = 3.0            # global rank 0 = group 0 inner rank 0
    perm = leader_mod.choose_leader_perm(counts, 2, 4, health)
    # The carrying role 0 goes to the healthiest rank; the slow rank is
    # parked in a carry-free role.  Group 1 (uniform) stays identity.
    assert perm == ((1, 2, 3, 0), (0, 1, 2, 3))
    assert leader_mod.permutation_cost(counts, 2, 4, perm, health) \
        < leader_mod.permutation_cost(counts, 2, 4, None, health)
    # Exclusion demotes even when health carries no signal.
    assert leader_mod.choose_leader_perm(counts, 2, 4, exclude=(0,)) \
        == ((1, 2, 3, 0), (0, 1, 2, 3))


def test_rank_health_from_rank_rings():
    from repro.core._exec_stats import EXEC_TELEMETRY
    from repro.runtime import leader as leader_mod

    digest = "unit-rank-health"
    try:
        for r in range(4):
            for _ in range(3):
                EXEC_TELEMETRY.record_rank(digest, r,
                                           0.3 if r == 2 else 0.1)
        h = leader_mod.rank_health(digest, 4)
        assert h[2] == pytest.approx(3.0, rel=0.01)
        np.testing.assert_allclose(h[[0, 1, 3]], 1.0, rtol=0.01)
    finally:
        EXEC_TELEMETRY.reset_rank_rings(digest)
    # A single sampled rank has no median to anchor on: all nominal.
    try:
        EXEC_TELEMETRY.record_rank("unit-rank-health-one", 0, 9.0)
        np.testing.assert_array_equal(
            leader_mod.rank_health("unit-rank-health-one", 4), np.ones(4))
    finally:
        EXEC_TELEMETRY.reset_rank_rings("unit-rank-health-one")


def test_replan_rung0_leader_rebake_then_recovery_rearms():
    from repro.core._exec_stats import EXEC_TELEMETRY
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("o", "i"))
    old = _hier_stub_plan("lead-old")
    cache = _TrackingCache("-r0")
    try:
        # Rank rings blame global rank 0 (3x the median p50).
        for r in range(8):
            for _ in range(4):
                EXEC_TELEMETRY.record_rank("lead-old", r,
                                           0.3 if r == 0 else 0.1)
        mgr = replan_mod.ReplanManager(old, mesh, cache, background=False)
        mgr.trigger({"kind": "sustained_skew", "worst_rank": 0,
                     "baseline_s": 0.1})
        assert mgr.observe()               # rung 0 swap installs
        new = mgr.plan
        assert new.spec.variant == "fence_hierarchy"
        assert new.spec.hier_leader_perm == ((1, 2, 3, 0), (0, 1, 2, 3))
        assert mgr.leader_rebakes == 1 and old.freed
        assert cache.built == ["fence_hierarchy"]   # no sweep, no fence
        ev = mgr.events[-1]
        assert ev["event"] == "swap" and ev["kind"] == "leader_rebake"
        # Provenance landed in the live decision tier, keyed perm-free.
        (choice,) = cache.auto_choices.values()
        assert choice["leader_rebake"]["leader_perm"] == \
            [[1, 2, 3, 0], [0, 1, 2, 3]]
        # The new plan earns a healthy baseline: the ladder re-arms at 0.
        assert mgr._ladder_stage == 1
        ring = EXEC_TELEMETRY.ring(new.signature.digest)
        for _ in range(mgr.monitor.warmup):
            ring.record(0.1)
        assert not mgr.observe()
        assert mgr.events[-1]["event"] == "recovered"
        assert mgr._ladder_stage == 0
    finally:
        EXEC_TELEMETRY.reset_rank_rings("lead-old")


def test_replan_rung0_ineligible_without_blamed_rank(monkeypatch):
    """No worst_rank -> rung 0 falls through to the sandbox sweep."""
    from repro.launch.mesh import make_mesh

    def fake_autotune(spec, mesh, cache, **kw):
        w = _StubPlan(spec, "sandbox-winner")
        w.auto_choice = {"variant": "fence", "codec": "identity"}
        return w

    monkeypatch.setattr(replan_mod, "autotune_variant", fake_autotune)
    mesh = make_mesh((1, 1), ("o", "i"))
    old = _hier_stub_plan("lead-noblame")
    cache = _TrackingCache("-nb")
    mgr = replan_mod.ReplanManager(old, mesh, cache, background=False)
    mgr.trigger({"kind": "sustained_skew", "worst_rank": None})
    assert mgr.observe()
    assert mgr.leader_rebakes == 0
    assert mgr.plan.spec.variant == "fence" and mgr._ladder_stage == 2


def test_replan_ladder_escalates_to_fence_then_exhausts(monkeypatch):
    import jax.numpy as jnp

    from repro.core.plan import AlltoallvSpec
    from repro.launch.mesh import make_mesh

    def fake_autotune(spec, mesh, cache, **kw):
        w = _StubPlan(spec, "sandbox-winner")
        w.auto_choice = {"variant": "lock", "codec": "identity"}
        return w

    monkeypatch.setattr(replan_mod, "autotune_variant", fake_autotune)
    mesh = make_mesh((1,), ("x",))
    spec = AlltoallvSpec(np.array([[3]]), (4,), jnp.float32, ("x",),
                         variant="lock")
    old = _StubPlan(spec, "ladder-old")
    cache = _TrackingCache("-lad")
    mgr = replan_mod.ReplanManager(old, mesh, cache, background=False)
    # Rung 0 is ineligible (not a hierarchy plan): trigger 1 re-autotunes.
    mgr.trigger("unit")
    assert mgr.observe() and mgr.plan.spec.variant == "lock"
    assert mgr._ladder_stage == 2
    # Trigger 2: degrade to the paper's safe default.
    mgr.trigger("unit")
    assert mgr.observe() and mgr.plan.spec.variant == "fence"
    assert mgr._ladder_stage == 3
    # Trigger 3: ladder exhausted — no further builds, monitor re-baselined.
    built_before = list(cache.built)
    mgr.trigger("unit")
    assert not mgr.observe()
    assert cache.built == built_before
    assert mgr.events[-1]["event"] == "ladder_exhausted"
    assert [e["event"] for e in mgr.events] == \
        ["swap", "swap", "ladder_exhausted"]
    assert mgr.leader_rebakes == 0


def test_replan_close_joins_and_frees_pending_plan(monkeypatch):
    """Satellite: close() must not leak a re-planned-but-never-installed
    plan's window slots when the loop stops before the next observe()."""
    import time as _time

    import jax.numpy as jnp

    from repro.core.plan import AlltoallvSpec
    from repro.launch.mesh import make_mesh

    def slow_autotune(spec, mesh, cache, **kw):
        _time.sleep(0.05)
        w = _StubPlan(spec, "sandbox-winner")
        w.auto_choice = {"variant": "fence", "codec": "identity"}
        return w

    monkeypatch.setattr(replan_mod, "autotune_variant", slow_autotune)
    mesh = make_mesh((1,), ("x",))
    spec = AlltoallvSpec(np.array([[3]]), (4,), jnp.float32, ("x",),
                         variant="lock")
    old = _StubPlan(spec, "close-old")
    cache = _TrackingCache("-close")
    mgr = replan_mod.ReplanManager(old, mesh, cache, background=True)
    mgr.trigger("unit")                    # background sweep in flight
    mgr.close()
    assert mgr._thread is None and mgr._pending is None
    assert cache.plans and cache.plans[-1].freed   # pending plan released
    assert mgr.plan is old and not old.freed       # live plan untouched
    mgr.close()                            # idempotent
    assert not mgr.observe()               # nothing left to install


def test_install_resets_stale_rank_rings():
    """Satellite: a hot-swap re-anchors the incoming digest's per-rank
    rings so stale samples from a prior tenure cannot drive attribution."""
    import jax.numpy as jnp

    from repro.core._exec_stats import EXEC_TELEMETRY
    from repro.core.plan import AlltoallvSpec
    from repro.launch.mesh import make_mesh

    # Direct unit: reset drops exactly the digest's rings.
    tel = ExecTelemetry()
    tel.record_rank("d", 0, 0.1)
    tel.record_rank("d", 1, 0.2)
    tel.record_rank("e", 0, 0.1)
    assert tel.reset_rank_rings("d") == 2
    assert tel.rank_summary("d") == {}
    assert list(tel.rank_summary("e")) == [0]

    # End to end through ReplanManager._install.
    mesh = make_mesh((1,), ("x",))
    spec = AlltoallvSpec(np.array([[3]]), (4,), jnp.float32, ("x",),
                         variant="lock")
    old = _StubPlan(spec, "s2-old")
    new = _StubPlan(spec, "s2-new")
    EXEC_TELEMETRY.record_rank("s2-new", 0, 0.4)   # stale prior tenure
    EXEC_TELEMETRY.record_rank("s2-new", 1, 0.1)
    assert EXEC_TELEMETRY.rank_summary("s2-new")
    mgr = replan_mod.ReplanManager(
        old, mesh, _TrackingCache("-s2"), background=False)
    assert mgr.force_swap(new)
    assert EXEC_TELEMETRY.rank_summary("s2-new") == {}
    assert old.freed and mgr.plan is new


def test_metrics_count_leader_rebakes():
    from repro.obs.metrics import render_metrics

    snap = {"swaps": [{"reason": {"kind": "leader_rebake"}},
                      {"reason": {"kind": "sustained_skew"}},
                      {"reason": "forced"}],
            "plans": {}, "ranks": {}}
    text = render_metrics(exec_snapshot=snap)
    assert "repro_plan_swaps_total 3" in text
    assert "repro_leader_rebakes_total 1" in text
