"""Resilience runtime: straggler/skew detection, recovery replay, chaos
injection, and elastic count resharding (single-device; the end-to-end
flows run as dist cases replan_hot_swap / elastic_resume / chaos_recovery)."""

import numpy as np
import pytest

from repro.core._exec_stats import EpochRing, ExecTelemetry
from repro.runtime import chaos as chaos_mod
from repro.runtime import fault as fault_mod
from repro.runtime import replan as replan_mod
from repro.runtime.straggler import PlanSkewMonitor, StragglerDetector


# --- StragglerDetector ------------------------------------------------------

def test_stop_without_start_returns_none():
    det = StragglerDetector()
    assert det.stop(step=0) is None
    assert det.count == 0 and det.ema is None and det.last_step is None


def _feed(det, durations, monkeypatch):
    """Drive the detector with scripted step durations via a fake clock."""
    from repro.runtime import straggler
    clock = {"t": 0.0}
    monkeypatch.setattr(straggler.time, "perf_counter", lambda: clock["t"])
    out = []
    for i, dt in enumerate(durations):
        det.start()
        clock["t"] += dt
        out.append(det.stop(i))
    return out


def test_ema_flags_slow_step_after_warmup(monkeypatch):
    det = StragglerDetector(threshold=2.0, ema_alpha=0.5, warmup_steps=2)
    reports = _feed(det, [0.1, 0.1, 0.1, 0.1, 0.5, 0.1], monkeypatch)
    assert [r is not None for r in reports] == [False] * 4 + [True, False]
    rep = reports[4]
    assert rep.step == 4 and rep.ratio == pytest.approx(5.0, rel=0.01)
    # The flagged step entered the EMA at alpha/4, not alpha: the average
    # must not have jumped toward the outlier.
    assert det.ema < 0.2


def test_checkpoint_early_windows_by_step_recency(monkeypatch):
    det = StragglerDetector(threshold=2.0, ema_alpha=0.1, warmup_steps=2,
                            window_steps=5)
    # Two slow steps far apart: each flags, but never 2 within the window.
    fast, slow = [0.1] * 20, 0.5
    durations = fast[:5] + [slow] + fast[:10] + [slow]
    _feed(det, durations, monkeypatch)
    assert len(det.flagged) == 2
    assert not det.should_checkpoint_early()
    # ...now two slow steps close together => degrading fleet.
    det2 = StragglerDetector(threshold=2.0, ema_alpha=0.1, warmup_steps=2,
                             window_steps=5)
    _feed(det2, fast[:5] + [slow, 0.1, slow], monkeypatch)
    assert len(det2.flagged) == 2
    assert det2.should_checkpoint_early()


# --- RetryPolicy / classify / recovery replay -------------------------------

def test_retry_policy_decays_on_sustained_progress():
    pol = fault_mod.RetryPolicy(max_restarts=3, backoff_seconds=0.0,
                                decay_after=3)
    pol.record_failure(5, RuntimeError("x"))
    pol.record_failure(9, RuntimeError("y"))
    assert pol.restarts == 2
    for _ in range(3):
        pol.record_success()
    assert pol.restarts == 1          # one restart forgiven
    pol.record_success()              # streak restarts after a decay
    assert pol.restarts == 1
    for _ in range(2):
        pol.record_success()
    assert pol.restarts == 0
    for _ in range(10):
        pol.record_success()          # never decays below zero
    assert pol.restarts == 0


def test_retry_policy_failure_resets_streak():
    pol = fault_mod.RetryPolicy(max_restarts=5, backoff_seconds=0.0,
                                decay_after=3)
    pol.record_failure(1, RuntimeError("a"))
    pol.record_success()
    pol.record_success()
    pol.record_failure(4, RuntimeError("b"))   # streak back to 0
    pol.record_success()
    pol.record_success()
    assert pol.restarts == 2          # 2 clean steps < decay_after
    pol.record_success()
    assert pol.restarts == 1


def test_retry_policy_exhaustion_raises():
    pol = fault_mod.RetryPolicy(max_restarts=1, backoff_seconds=0.0)
    pol.record_failure(0, RuntimeError("a"))
    with pytest.raises(fault_mod.FaultError):
        pol.record_failure(1, RuntimeError("b"))


def test_classify_failure():
    assert fault_mod.classify_failure(RuntimeError("oops")) == "transient"
    assert fault_mod.classify_failure(
        chaos_mod.ChaosError("chaos: injected step fault at step 4")) \
        == "transient"
    assert fault_mod.classify_failure(
        chaos_mod.ChaosError("chaos: device lost during step 8")) \
        == "device_loss"
    assert fault_mod.classify_failure(
        chaos_mod.ChaosError("chaos: window allocation failed")) \
        == "device_loss"
    err = type("XlaRuntimeError", (RuntimeError,), {})("anything")
    assert fault_mod.classify_failure(err) == "device_loss"


def test_run_with_recovery_replays_and_rebuilds():
    ran, recoveries, rebuilds = [], [], []
    fired = set()

    def run_step(step):
        if step == 3 and "t" not in fired:
            fired.add("t")
            raise RuntimeError("flaky step")
        if step == 6 and "d" not in fired:
            fired.add("d")
            raise RuntimeError("device dead")
        ran.append(step)
        return {"step": step}

    def restore():
        return (max(ran) + 1) if ran else 0

    final = fault_mod.run_with_recovery(
        run_step, restore=restore, start_step=0, n_steps=9,
        policy=fault_mod.RetryPolicy(max_restarts=3, backoff_seconds=0.0),
        rebuild_plans=lambda err: rebuilds.append(str(err)),
        on_recovery=lambda s, e, k: recoveries.append((s, k)))
    assert final == 9
    assert ran == list(range(9))      # replay is exact: no step skipped/duped
    assert recoveries == [(3, "transient"), (6, "device_loss")]
    # Plans rebuilt ONLY for the device-loss-class failure.
    assert rebuilds == ["device dead"]


# --- chaos injection --------------------------------------------------------

def test_chaos_same_seed_same_schedule():
    def schedule(seed, n=60):
        inj = chaos_mod.ChaosInjector(seed=seed, window_fail_rate=0.3)
        out = []
        for _ in range(n):
            try:
                inj.maybe_fail_window()
                out.append(False)
            except chaos_mod.ChaosError:
                out.append(True)
        return out

    a, b, c = schedule(7), schedule(7), schedule(8)
    assert a == b                     # identical replay
    assert a != c                     # a different seed is a different world
    assert any(a) and not all(a)


def test_chaos_step_faults_fire_once_stalls_every_visit():
    inj = chaos_mod.ChaosInjector(seed=0, fail_steps=(4,),
                                  device_loss_steps=(8,),
                                  stall_steps=(2,), stall_seconds=0.001)
    with pytest.raises(chaos_mod.ChaosError):
        inj.step_hook(4)
    inj.step_hook(4)                  # recovery replay makes progress
    with pytest.raises(chaos_mod.ChaosError):
        inj.step_hook(8)
    inj.step_hook(8)
    inj.step_hook(2)
    inj.step_hook(2)                  # a degraded host is slow on replay too
    assert inj.injected == {"window": 0, "poison": 0, "stall": 2,
                            "step": 1, "device": 1}


def test_chaos_parse_spec():
    inj = chaos_mod.ChaosInjector.parse(
        "seed=7,window_fail=0.25,fail_step=4+9,device_loss_step=11,"
        "stall_steps=3-5,stall_seconds=0.1")
    assert inj.seed == 7 and inj.window_fail_rate == 0.25
    assert inj.fail_steps == {4, 9}
    assert inj.device_loss_steps == {11}
    assert inj.stall_steps == {3, 4, 5} and inj.stall_seconds == 0.1
    with pytest.raises(ValueError):
        chaos_mod.ChaosInjector.parse("frobnicate=1")
    with pytest.raises(ValueError):
        chaos_mod.ChaosInjector.parse("seed")


def test_poison_store_reads_as_miss_not_crash(tmp_path):
    import jax.numpy as jnp

    from repro.core.autotune import decision_signature
    from repro.core.plan import AlltoallvSpec
    from repro.launch.mesh import make_mesh
    from repro.planstore import PlanStore

    mesh = make_mesh((1,), ("x",))
    spec = AlltoallvSpec(np.array([[3]]), (4,), jnp.float32, ("x",),
                         variant="lock")
    sig = decision_signature(spec, mesh)
    store = PlanStore(str(tmp_path))
    store.put_auto(sig, {"variant": "lock", "codec": "identity"})
    assert store.get_auto(sig)["variant"] == "lock"

    inj = chaos_mod.ChaosInjector(seed=1)
    assert inj.poison_store(store) >= 1
    assert inj.injected["poison"] >= 1
    assert store.get_auto(sig) is None      # corruption degrades to a miss
    assert store.invalid >= 1 or store.errors >= 1


# --- EpochRing / PlanSkewMonitor --------------------------------------------

def test_epoch_ring_wraparound_and_clamping():
    ring = EpochRing(capacity=4)
    assert ring.window(0, 10).size == 0 and ring.last(3).size == 0
    for i in range(10):
        ring.record(float(i))
    assert ring.count == 10
    np.testing.assert_array_equal(ring.last(2), [8.0, 9.0])
    np.testing.assert_array_equal(ring.window(6, 10), [6.0, 7.0, 8.0, 9.0])
    assert ring.window(0, 4).size == 0          # fully evicted
    np.testing.assert_array_equal(ring.window(5, 8), [6.0, 7.0])  # clamped
    np.testing.assert_array_equal(ring.window(8, 99), [8.0, 9.0])


def test_skew_monitor_sustained_not_spike():
    tel = ExecTelemetry()
    ring = tel.ring("digest-a")
    mon = PlanSkewMonitor(ring, threshold=1.5, window=2, sustain=2, warmup=4)
    for _ in range(4):
        ring.record(0.010)
    assert mon.observe() is None                # baseline only
    ring.record(0.100)
    ring.record(0.100)
    assert mon.observe() is None                # 1 hot window: a spike
    ring.record(0.010)
    ring.record(0.010)
    assert mon.observe() is None                # cool window resets the run
    for _ in range(4):
        ring.record(0.100)
    rep = mon.observe()                         # 2 consecutive hot windows
    assert rep is not None and rep.windows_hot == 2
    assert rep.ratio == pytest.approx(10.0, rel=0.05)
    assert rep.baseline == pytest.approx(0.010, rel=0.01)


def test_skew_monitor_reset_reanchors_baseline():
    tel = ExecTelemetry()
    ring = tel.ring("digest-b")
    mon = PlanSkewMonitor(ring, threshold=1.5, window=2, sustain=1, warmup=2)
    for _ in range(2):
        ring.record(0.010)
    for _ in range(2):
        ring.record(0.100)
    assert mon.observe() is not None
    mon.reset()
    # Post-reset the baseline is the NEW normal (0.1s), not the stale one:
    # the same level that just triggered must no longer count as skew.
    for _ in range(4):
        ring.record(0.100)
    assert mon.observe() is None
    assert mon.baseline == pytest.approx(0.100, rel=0.01)


def test_skew_monitor_attribution_to_compute():
    tel = ExecTelemetry()
    plan_ring, compute_ring = tel.ring("plan"), tel.ring("compute")
    mon = PlanSkewMonitor(plan_ring, threshold=1.5, window=2, sustain=1,
                          warmup=2, compute_ring=compute_ring,
                          attribution=1.0)
    for _ in range(2):
        plan_ring.record(0.010)
        compute_ring.record(0.050)
    for _ in range(2):
        plan_ring.record(0.100)      # plan 10x...
        compute_ring.record(0.750)   # ...but compute 15x: whole host is slow
    assert mon.observe() is None     # not the plan's fault — no re-plan
    plan2, comp2 = tel.ring("plan2"), tel.ring("compute2")
    mon2 = PlanSkewMonitor(plan2, threshold=1.5, window=2, sustain=1,
                           warmup=2, compute_ring=comp2, attribution=1.0)
    for _ in range(2):
        plan2.record(0.010)
        comp2.record(0.050)
    for _ in range(2):
        plan2.record(0.100)          # plan 10x, compute flat: blame the plan
        comp2.record(0.050)
    assert mon2.observe() is not None


# --- replan: degrade-to-fence + reshard_counts ------------------------------

class _StubPlan:
    def __init__(self, spec, digest):
        self.spec = spec
        self.signature = type("Sig", (), {"digest": digest})()
        self.auto_choice = None
        self.freed = False

    def free(self):
        self.freed = True


class _StubCache:
    """PlanCache stand-in: hands out stub plans keyed by spec.variant."""

    def __init__(self):
        self.auto_choices = {}
        self.built = []

    def get(self, spec, mesh, store=None):
        self.built.append(spec.variant)
        return _StubPlan(spec, f"digest-{spec.variant}")


def test_replan_degrades_to_fence_when_autotuner_faults(monkeypatch):
    import jax.numpy as jnp

    from repro.core.plan import AlltoallvSpec
    from repro.launch.mesh import make_mesh

    def boom(*a, **k):
        raise RuntimeError("autotuner exploded")

    monkeypatch.setattr(replan_mod, "autotune_variant", boom)
    mesh = make_mesh((1,), ("x",))
    spec = AlltoallvSpec(np.array([[3]]), (4,), jnp.float32, ("x",),
                         variant="lock")
    old = _StubPlan(spec, "digest-old")
    cache = _StubCache()
    mgr = replan_mod.ReplanManager(old, mesh, cache, background=False)
    mgr.trigger("unit")
    assert mgr.observe()                    # degraded plan installs
    assert mgr.replans_completed == 1
    new = mgr.plan
    assert new.spec.variant == "fence" and old.freed
    choice = new.auto_choice
    assert choice["variant"] == "fence" and "degraded" in choice
    assert choice["replan"]["kind"] == "unit"
    assert list(cache.auto_choices.values()) == [choice]
    ev = mgr.events[-1]
    assert ev["event"] == "swap" and ev["variant_to"] == "fence"


def test_reshard_counts_shrink_grow_conserve():
    rng = np.random.default_rng(0)
    c = rng.integers(0, 9, size=(8, 8))
    down = replan_mod.reshard_counts(c, 4)
    assert down.shape == (4, 4) and down.sum() == c.sum()
    # Block sums exactly: new rank r is old ranks {2r, 2r+1}.
    np.testing.assert_array_equal(
        down, c.reshape(4, 2, 4, 2).sum(axis=(1, 3)))
    up = replan_mod.reshard_counts(c, 16)
    assert up.shape == (16, 16) and up.sum() == c.sum()
    # The split is a partition of each old cell over its successor block.
    np.testing.assert_array_equal(
        up.reshape(8, 2, 8, 2).sum(axis=(1, 3)), c)
    np.testing.assert_array_equal(replan_mod.reshard_counts(c, 8), c)
    with pytest.raises(ValueError):
        replan_mod.reshard_counts(c, 3)     # coprime: no principled split
    with pytest.raises(ValueError):
        replan_mod.reshard_counts(c[0], 4)  # not square
