"""Pallas kernel tests: shape/dtype sweep vs pure-jnp oracles.

The gather kernel is local (single device, HLO interpreter); the remote-DMA
a2a kernels need multiple devices and run via tests/test_distributed.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("rows,feat,dtype", [
    (32, 128, jnp.float32),
    (40, 100, jnp.float32),      # unaligned feature -> lane padding
    (64, 256, jnp.bfloat16),
    (8, 64, jnp.float32),
    (128, 512, jnp.float16),
])
def test_gather_rows_sweep(rows, feat, dtype):
    rng = np.random.default_rng(rows + feat)
    x = jnp.asarray(rng.standard_normal((rows, feat)), dtype)
    n = ((rows * 2 + 7) // 8) * 8
    idx = jnp.asarray(rng.integers(0, rows, n), jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    got = ops.pack(x, idx, valid)
    want = ref.pack_ref(x, idx, valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-6)


def test_gather_multi_dim_features():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 3, 5)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 16, 24), jnp.int32)
    valid = jnp.ones(24, jnp.int32)
    got = ops.unpack(x, idx, valid)
    want = ref.unpack_ref(x, idx, valid)
    np.testing.assert_allclose(got, want)


@settings(max_examples=10)
@given(st.integers(1, 40), st.integers(1, 130), st.data())
def test_gather_rows_property(rows, feat, data):
    """Hypothesis: any index map + mask matches the oracle exactly."""
    n = data.draw(st.integers(1, 8)) * 8
    rng = np.random.default_rng(rows * 1000 + feat)
    x = jnp.asarray(rng.standard_normal((rows, feat)), jnp.float32)
    idx = jnp.asarray(
        data.draw(st.lists(st.integers(0, rows - 1), min_size=n, max_size=n)),
        jnp.int32)
    valid = jnp.asarray(
        data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)),
        jnp.int32)
    got = ops.pack(x, idx, valid)
    want = ref.pack_ref(x, idx, valid)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_a2a_oracle_is_involution():
    """The bucket-transpose oracle applied twice is the identity."""
    rng = np.random.default_rng(1)
    p, cap, f = 4, 8, 16
    x = rng.standard_normal((p, p * cap, f)).astype(np.float32)
    once = ref.a2a_bucketed_ref(x, p, cap)
    twice = ref.a2a_bucketed_ref(once, p, cap)
    np.testing.assert_array_equal(twice, x)
