"""Chunked linear-recurrence engines vs step-by-step recurrent oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import scan_utils


def _mamba_inputs(b=2, s=24, c=8, n=4, seed=0):
    rng = np.random.default_rng(seed)
    delta = jnp.asarray(np.abs(rng.standard_normal((b, s, c))) * 0.5, jnp.float32)
    a_log = jnp.asarray(np.log(np.abs(rng.standard_normal((c, n))) + 0.5),
                        jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, c)), jnp.float32)
    return delta, a_log, bm, cm, x


@pytest.mark.parametrize("chunk", [4, 8, 24])
def test_chunked_mamba_matches_stepwise(chunk):
    delta, a_log, bm, cm, x = _mamba_inputs()
    y = scan_utils.chunked_mamba_scan(delta, a_log, bm, cm, x, chunk=chunk)
    # step-by-step oracle via the decode kernel
    b, s, c = x.shape
    h = jnp.zeros((b, c, a_log.shape[1]), jnp.float32)
    ys = []
    for t in range(s):
        h, yt = scan_utils.mamba_decode_step(h, delta[:, t], a_log,
                                             bm[:, t], cm[:, t], x[:, t])
        ys.append(yt)
    oracle = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)


def test_chunked_mamba_final_state():
    delta, a_log, bm, cm, x = _mamba_inputs(seed=3)
    y, h_end = scan_utils.chunked_mamba_scan(delta, a_log, bm, cm, x,
                                             chunk=8, return_final_state=True)
    b, s, c = x.shape
    h = jnp.zeros((b, c, a_log.shape[1]), jnp.float32)
    for t in range(s):
        h, _ = scan_utils.mamba_decode_step(h, delta[:, t], a_log,
                                            bm[:, t], cm[:, t], x[:, t])
    np.testing.assert_allclose(np.asarray(h_end), np.asarray(h),
                               rtol=2e-5, atol=2e-5)


def _mlstm_inputs(b=2, s=16, h=2, dk=8, dv=8, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *sh: jnp.asarray(rng.standard_normal(sh), jnp.float32)
    q, k, v = mk(b, s, h, dk), mk(b, s, h, dk), mk(b, s, h, dv)
    log_i = mk(b, s, h) * 0.5
    log_f = jax.nn.log_sigmoid(mk(b, s, h) + 2.0)
    return q, k, v, log_i, log_f


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunkwise_mlstm_matches_stepwise(chunk):
    q, k, v, log_i, log_f = _mlstm_inputs()
    y = scan_utils.chunkwise_mlstm(q, k, v, log_i, log_f, chunk=chunk)
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    state = (jnp.zeros((b, h, dk, dv)), jnp.zeros((b, h, dk)),
             jnp.full((b, h), -1e30))
    ys = []
    for t in range(s):
        state, yt = scan_utils.mlstm_decode_step(
            state, q[:, t], k[:, t], v[:, t], log_i[:, t], log_f[:, t])
        ys.append(yt)
    oracle = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=3e-5, atol=3e-5)


def test_mlstm_gate_extremes_stable():
    """Exponential input gates with large pre-activations must not overflow
    (the m-stabilizer claim)."""
    q, k, v, log_i, log_f = _mlstm_inputs(seed=5)
    y = scan_utils.chunkwise_mlstm(q, k, v, log_i + 40.0, log_f, chunk=8)
    assert bool(jnp.all(jnp.isfinite(y)))
    y2 = scan_utils.chunkwise_mlstm(q, k, v, log_i, log_f - 40.0, chunk=8)
    assert bool(jnp.all(jnp.isfinite(y2)))


def test_flash_attention_matches_direct():
    from repro.models import attention as att

    rng = np.random.default_rng(0)
    b, sq, n, g, dh = 1, 2048, 2, 1, 16
    q = jnp.asarray(rng.standard_normal((b, sq, n, g, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, n, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, n, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    o_f = att._flash_attention(q, k, v, pos, pos, True, dh ** -0.5)
    mask = pos[:, :, None] >= pos[:, None, :]
    o_d = att._direct_attention(q, k, v, mask, dh ** -0.5)
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_d),
                               rtol=2e-5, atol=2e-5)
