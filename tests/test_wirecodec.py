"""Wire-codec property tests: round-trip error bounds, scale inlining,
and the lossy opt-in contract (distributed parity in test_distributed.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.parallel import wirecodec

LOSSY = [n for n in wirecodec.CODECS if wirecodec.CODECS[n].lossy]


def _rows(seed, rows=32, d=24, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, d)) * scale, jnp.float32)


@settings(max_examples=20)
@given(st.integers(0, 10_000), st.floats(1e-4, 1e3))
def test_roundtrip_error_bound(seed, scale):
    """Every codec's measured round-trip error respects its declared
    per-element bound relative to the row max (the quantity error_tol
    gates on)."""
    x = _rows(seed, scale=scale)
    row_max = np.asarray(jnp.max(jnp.abs(x), axis=1, keepdims=True))
    for name, c in wirecodec.CODECS.items():
        wire, scales = c.encode(x)
        back = np.asarray(c.decode(wire, scales, jnp.float32))
        err = np.abs(back - np.asarray(x))
        bound = c.rel_error * row_max + 1e-6 * scale
        assert (err <= bound).all(), (name, float(err.max()))
        if name == "identity":
            np.testing.assert_array_equal(back, np.asarray(x))


def test_declared_wire_dtypes():
    assert wirecodec.get("identity").wire_dtype is None
    assert wirecodec.get("bf16").wire_dtype == jnp.bfloat16
    assert wirecodec.get("int8").wire_dtype == jnp.int8
    assert wirecodec.get("identity").scale_lanes == 0
    assert wirecodec.get("bf16").scale_lanes == 0
    assert wirecodec.get("int8").scale_lanes == 4
    assert wirecodec.get("int8").ratio == 4.0


@settings(max_examples=15)
@given(st.integers(0, 10_000))
def test_scale_inline_roundtrip_bitexact(seed):
    """inline_rows/split_rows is a pure bitcast shuttle: the scale channel
    survives the payload ride bit-for-bit, for every scaled codec."""
    x = _rows(seed)
    for name in LOSSY:
        c = wirecodec.get(name)
        if not c.has_scales:
            continue
        wire, scales = c.encode(x)
        k = wirecodec.inline_lanes(wire, scales)
        assert k == c.scale_lanes > 0
        packed = wirecodec.inline_rows(wire, scales, k)
        assert packed.shape == (x.shape[0], x.shape[1] + k)
        assert packed.dtype == wire.dtype
        w2, s2 = wirecodec.split_rows(packed, k)
        np.testing.assert_array_equal(np.asarray(w2), np.asarray(wire))
        np.testing.assert_array_equal(np.asarray(s2), np.asarray(scales))


def test_inline_lanes_gating():
    x = _rows(0)
    wire, scales = wirecodec.get("int8").encode(x)
    assert wirecodec.inline_lanes(wire, None) == 0          # no side channel
    assert wirecodec.inline_lanes(wire.reshape(32, 6, 4), scales) == 0
    assert wirecodec.inline_lanes(x, scales) == 1           # f32 wire: 1 lane


def test_zero_rows_no_nan():
    x = jnp.zeros((4, 8), jnp.float32)
    for name in LOSSY:
        c = wirecodec.get(name)
        wire, scales = c.encode(x)
        back = np.asarray(c.decode(wire, scales, jnp.float32))
        assert np.isfinite(back).all()
        np.testing.assert_array_equal(back, 0.0)


def test_lossy_opt_in_contract():
    """Lossy codecs are never silently enabled: require() admits identity
    with no tolerance, rejects lossy codecs without one (or with one below
    the declared bound), and rejects unknown names."""
    assert wirecodec.require("identity", None).name == "identity"
    for name in LOSSY:
        c = wirecodec.get(name)
        with pytest.raises(ValueError, match="never"):
            wirecodec.require(name, None)
        with pytest.raises(ValueError, match="never"):
            wirecodec.require(name, c.rel_error / 2)
        assert wirecodec.require(name, c.rel_error).name == name
    with pytest.raises(ValueError, match="unknown"):
        wirecodec.require("zstd", 1.0)


def test_allowed_ordering():
    assert wirecodec.allowed(None) == ("identity",)
    names = wirecodec.allowed(1.0)
    assert set(names) == set(wirecodec.CODECS)
    bits = [wirecodec.CODECS[n].wire_bits for n in names]
    assert bits == sorted(bits)          # cheapest wire first
    with pytest.raises(ValueError):
        wirecodec.allowed(-0.1)


def test_fused_unpack_matmul_scales_fold():
    """The scales argument of fused_unpack_matmul equals decode-then-gather
    -then-matmul: the decode genuinely folded into the consumer."""
    from repro.kernels import ops as kops

    rng = np.random.default_rng(5)
    rows, d, e, n, f = 64, 16, 4, 8, 12
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    c = wirecodec.get("int8")
    wire, scales = c.encode(x)
    idx = jnp.asarray(rng.integers(0, rows, (e, n)), jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, (e, n)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((e, d, f)) * 0.1, jnp.float32)

    got = kops.fused_unpack_matmul(wire, idx, w, valid=valid, scales=scales)
    dec = c.decode(wire, scales, jnp.float32)
    h = jnp.take(dec, idx.reshape(-1), axis=0).reshape(e, n, d)
    ref = jnp.einsum("end,edf->enf",
                     h * valid.reshape(e, n, 1).astype(jnp.float32), w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_moe_config_codec_gate():
    """MoEDispatchPlan.build rejects a lossy wire_codec without codec_tol
    (same contract as the generic INIT) on a single device."""
    import dataclasses

    from repro.configs.base import MoEConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import moe as moe_mod

    mesh = make_host_mesh(1)
    base = MoEConfig(n_experts=4, top_k=2, d_expert=8,
                     dispatch="persistent_a2a")
    with pytest.raises(ValueError, match="never"):
        moe_mod.MoEDispatchPlan.build(
            dataclasses.replace(base, wire_codec="int8"), 16, mesh,
            d_model=8, dtype=jnp.float32)
    plan = moe_mod.MoEDispatchPlan.build(
        dataclasses.replace(base, wire_codec="int8", codec_tol=0.01), 16,
        mesh, d_model=8, dtype=jnp.float32)
    assert plan.codec == "int8"
