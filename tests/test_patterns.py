"""Property tests for the collective-agnostic exchange patterns.

Pure numpy: the baked pack/unpack tables of ``AllgathervPattern`` and
``ReduceScatterPattern`` are driven through a host-side simulation of the
wire (pack -> bucket exchange -> unpack, the reduction fused into unpack
for reduce-scatter) and compared against each pattern's own numpy oracle,
over dense / ragged / skewed count vectors at every mesh cardinality the
dist suites use ((2,4) and (4,2) both linearize to p=8; plus p=4, p=2).
"""

import numpy as np
import pytest

from _hypothesis_compat import given, strategies as st

from repro.core import metadata as md, patterns
from repro.core.plan import ExchangeSpec


def _counts(kind: str, p: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed + p)
    if kind == "dense":
        return rng.integers(16, 40, p)
    if kind == "ragged":
        c = rng.integers(0, 30, p)
        c[rng.integers(0, p)] = 0              # force at least one empty rank
        return c
    if kind == "skewed":
        c = rng.integers(1, 6, p)
        c[0] = 200                              # hot rank
        return c
    raise ValueError(kind)


def _simulate_allgatherv(counts, feature=(3,), tile=md.TILE_ROWS):
    """Host-side replay of the gatherv epoch off the baked tables."""
    pat = patterns.get("allgatherv")
    sc = pat.expand_counts(counts)
    p = sc.shape[0]
    send_rows = pat.send_rows(sc, tile)
    recv_rows = pat.recv_rows(sc, tile)
    cap = send_rows                             # gatherv: one bucket
    t = pat.bake_tables(sc, cap, recv_rows)
    rng = np.random.default_rng(1)
    bufs = rng.standard_normal((p, send_rows) + feature).astype(np.float32)

    own = np.where(t.pack_valid[..., None], bufs[np.arange(p)[:, None],
                                                 t.pack_src], 0.0)
    buckets = own.reshape((p * cap,) + feature)          # the all_gather wire
    out = np.where(t.unpack_valid[..., None],
                   buckets[t.unpack_src], 0.0)           # [p, recv_rows, F]
    want = pat.reference(bufs, counts, recv_rows)
    return out, want, (sc, cap, send_rows, recv_rows)


def _simulate_reduce_scatter(counts, feature=(3,), tile=md.TILE_ROWS):
    """Host-side replay of the RS epoch: the sum is fused into unpack."""
    pat = patterns.get("reduce_scatter")
    sc = pat.expand_counts(counts)
    p = sc.shape[0]
    send_rows = pat.send_rows(sc, tile)
    recv_rows = pat.recv_rows(sc, tile)
    cap = recv_rows                             # RS: one reduced bucket out
    t = pat.bake_tables(sc, cap, recv_rows)
    rng = np.random.default_rng(2)
    bufs = rng.standard_normal((p, send_rows) + feature).astype(np.float32)

    packed = np.where(t.pack_valid[..., None], bufs[np.arange(p)[:, None],
                                                    t.pack_src], 0.0)
    packed = packed.reshape((p, p, cap) + feature)       # [src, dst, cap, F]
    moved = packed.sum(axis=0)                           # fused reduction
    out = np.where(t.unpack_valid[..., None],
                   moved[np.arange(p)[:, None], t.unpack_src], 0.0)
    want = pat.reference(bufs, counts, recv_rows)
    return out, want, (sc, cap, send_rows, recv_rows)


@pytest.mark.parametrize("p", [2, 4, 8])        # 8 covers (2,4) and (4,2)
@pytest.mark.parametrize("kind", ["dense", "ragged", "skewed"])
def test_allgatherv_tables_roundtrip(kind, p):
    out, want, _ = _simulate_allgatherv(_counts(kind, p))
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("kind", ["dense", "ragged", "skewed"])
def test_reduce_scatter_tables_roundtrip(kind, p):
    out, want, _ = _simulate_reduce_scatter(_counts(kind, p))
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)


count_vectors = st.integers(2, 9).flatmap(
    lambda p: st.lists(st.integers(0, 40), min_size=p, max_size=p)
    .map(np.array))


@given(count_vectors)
def test_allgatherv_roundtrip_property(counts):
    out, want, _ = _simulate_allgatherv(counts)
    np.testing.assert_array_equal(out, want)


@given(count_vectors)
def test_reduce_scatter_roundtrip_property(counts):
    out, want, _ = _simulate_reduce_scatter(counts)
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)


@given(count_vectors)
def test_expanded_matrices_validate_and_conserve(counts):
    """expand_counts output passes the family's own structural validation
    and conserves totals: gatherv ships each contribution once per rank,
    RS receives exactly the per-destination totals."""
    ag = patterns.get("allgatherv")
    rs = patterns.get("reduce_scatter")
    m_ag, m_rs = ag.expand_counts(counts), rs.expand_counts(counts)
    ag.validate_matrix(m_ag)
    rs.validate_matrix(m_rs)
    p = len(counts)
    np.testing.assert_array_equal(m_ag, np.asarray(counts)[:, None] * np.ones((1, p), np.int64))
    np.testing.assert_array_equal(m_rs, np.ones((p, 1), np.int64) * np.asarray(counts)[None, :])
    # recv side: every gatherv rank receives the full concat; every RS rank
    # receives its own block from each source
    np.testing.assert_array_equal(md.recv_counts(m_ag).sum(axis=1),
                                  np.full(p, np.sum(counts)))
    np.testing.assert_array_equal(md.recv_counts(m_rs),
                                  np.asarray(counts)[:, None] * np.ones((1, p), np.int64))


def test_identity_detection_uniform_tile_aligned():
    p, c = 4, 2 * md.TILE_ROWS
    counts = np.full(p, c)
    ag = patterns.get("allgatherv")
    rs = patterns.get("reduce_scatter")
    sc_ag, sc_rs = ag.expand_counts(counts), rs.expand_counts(counts)
    assert ag.identity_maps(sc_ag, c, ag.send_rows(sc_ag, md.TILE_ROWS),
                            ag.recv_rows(sc_ag, md.TILE_ROWS))
    assert rs.identity_maps(sc_rs, c, rs.send_rows(sc_rs, md.TILE_ROWS),
                            rs.recv_rows(sc_rs, md.TILE_ROWS))
    ragged = counts.copy()
    ragged[1] -= 3
    sc_r = ag.expand_counts(ragged)
    cap = md.global_capacity(sc_r, md.TILE_ROWS)
    assert not ag.identity_maps(sc_r, cap, ag.send_rows(sc_r, md.TILE_ROWS),
                                ag.recv_rows(sc_r, md.TILE_ROWS))


def test_structural_validation_rejects_wrong_family():
    ag = patterns.get("allgatherv")
    rs = patterns.get("reduce_scatter")
    m = np.arange(16).reshape(4, 4)
    with pytest.raises(ValueError, match="row-constant"):
        ag.validate_matrix(m)
    with pytest.raises(ValueError, match="column-constant"):
        rs.validate_matrix(m)
    with pytest.raises(ValueError, match="unknown collective"):
        patterns.get("allreduce")


def test_spec_rejects_unsupported_combinations():
    counts = np.full(4, md.TILE_ROWS)
    base = dict(feature_shape=(4,), dtype=np.float32, axis=("x",))
    with pytest.raises(ValueError):
        ExchangeSpec(send_counts=patterns.as_matrix("reduce_scatter", counts),
                     variant="fence_hierarchy", collective="reduce_scatter",
                     **base)
    with pytest.raises(ValueError):
        ExchangeSpec(send_counts=patterns.as_matrix("reduce_scatter", counts),
                     variant="fence", codec="int8", collective="reduce_scatter",
                     **base)
    with pytest.raises(ValueError):
        ExchangeSpec(send_counts=patterns.as_matrix("allgatherv", counts),
                     variant="ragged", collective="allgatherv", **base)
    # the alltoallv spec is untouched by the generalization
    ExchangeSpec(send_counts=np.full((4, 4), 8), variant="fence", **base)
