"""Training-loop tests: convergence, checkpoint/restart fault tolerance,
straggler detection, data determinism."""

import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_reduced
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.train import ScheduleConfig, Trainer, TrainerConfig


def _bundle(arch="olmo-1b", steps=12, seq=128, batch=4):
    cfg = get_reduced(arch)
    shape = ShapeConfig("smoke", "train", seq, batch)
    mesh = make_mesh((1, 1), ("data", "model"))
    sched = ScheduleConfig(kind="cosine", peak_lr=3e-3, warmup_steps=2,
                           total_steps=steps)
    return steps_mod.make_train_bundle(cfg, shape, mesh, sched=sched)


def test_loss_decreases():
    bundle = _bundle(steps=15)
    trainer = Trainer(bundle, TrainerConfig(n_steps=15, log_every=100))
    result = trainer.run()
    hist = trainer.history
    first = np.mean([h["nll"] for h in hist[:3]])
    last = np.mean([h["nll"] for h in hist[-3:]])
    assert result["final_step"] == 15
    assert last < first - 0.05, f"no learning: {first:.3f} -> {last:.3f}"
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_fault_recovery_resumes_from_checkpoint():
    """A step failure mid-run restores the last checkpoint and replays."""
    with tempfile.TemporaryDirectory() as d:
        bundle = _bundle(steps=10)
        trainer = Trainer(bundle, TrainerConfig(
            n_steps=10, ckpt_dir=d, ckpt_every=4, log_every=100,
            async_ckpt=False))

        fired = {"n": 0}

        def failure_hook(step):
            if step == 6 and fired["n"] == 0:
                fired["n"] += 1
                raise RuntimeError("injected device failure")

        result = trainer.run(failure_hook=failure_hook)
        assert fired["n"] == 1
        assert result["final_step"] == 10
        # replayed steps 4..6 after restoring the step-4 checkpoint
        steps_seen = [h["step"] for h in trainer.history]
        assert steps_seen.count(5) == 2 or steps_seen.count(4) == 2

        # checkpoints on disk are complete and loadable
        assert trainer.ckpt.latest_step() is not None


def test_auto_resume():
    """A new Trainer over the same ckpt dir continues, not restarts."""
    with tempfile.TemporaryDirectory() as d:
        b1 = _bundle(steps=6)
        t1 = Trainer(b1, TrainerConfig(n_steps=6, ckpt_dir=d, ckpt_every=3,
                                       log_every=100, async_ckpt=False))
        t1.run()

        b2 = _bundle(steps=10)
        t2 = Trainer(b2, TrainerConfig(n_steps=10, ckpt_dir=d, ckpt_every=3,
                                       log_every=100, async_ckpt=False))
        result = t2.run()
        assert result["final_step"] == 10
        assert t2.history[0]["step"] == 6, "must resume at saved step"


def test_straggler_detector():
    from repro.runtime.straggler import StragglerDetector
    import time as _t

    det = StragglerDetector(threshold=3.0, warmup_steps=1)
    for step in range(6):
        det.start()
        _t.sleep(0.02)
        assert det.stop(step) is None
    det.start()
    _t.sleep(0.3)
    rep = det.stop(6)
    assert rep is not None and rep.ratio > 3.0
    assert not det.should_checkpoint_early()
    det.start(); _t.sleep(0.3); det.stop(7)
    assert det.should_checkpoint_early()


def test_data_determinism_and_restart():
    from repro.data import DataPipeline
    cfg = get_reduced("olmo-1b")
    p1 = DataPipeline(cfg, 64, 4, mesh=None, seed=3)
    p2 = DataPipeline(cfg, 64, 4, mesh=None, seed=3)
    b_stream = [np.asarray(next(p1)["tokens"]) for _ in range(4)]
    # restart from state: replay step 2 exactly
    p2.load_state_dict({"step": 2})
    np.testing.assert_array_equal(np.asarray(next(p2)["tokens"]), b_stream[2])
    # different seed differs
    p3 = DataPipeline(cfg, 64, 4, mesh=None, seed=4)
    assert not np.array_equal(np.asarray(next(p3)["tokens"]), b_stream[0])
