"""Hot-path metadata: host-baked index maps, round elision, signature keys,
window lifecycle.

These are the single-device halves of the persistent-path overhaul; the
multi-device output-identity checks live in test_distributed.py
(sparse_lock_elision / hierarchy_local_elision / fused_pack_fence /
pipelined_epochs).
"""

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, strategies as st
from repro.core import metadata as md, variants


counts_matrices = st.integers(2, 10).flatmap(
    lambda p: st.lists(
        st.lists(st.integers(0, 50), min_size=p, max_size=p),
        min_size=p, max_size=p).map(np.array))


@given(counts_matrices)
def test_baked_maps_match_in_graph_twins(counts):
    """Host-baked tables equal the traced twins bit-for-bit, for every rank —
    the persistent path computes the *same* maps, just once instead of per
    epoch."""
    p = counts.shape[0]
    cap = md.global_capacity(counts)
    recv_rows = max(md.max_total_recv(counts), 1)
    tables = md.baked_index_tables(counts, cap, recv_rows)
    sd = md.displacements(counts)
    rc = md.recv_counts(counts)
    rd = md.displacements(rc)
    for i in range(p):
        src, valid = variants.pack_index_map_in_graph(
            jnp.asarray(counts[i], jnp.int32), jnp.asarray(sd[i], jnp.int32),
            p, cap)
        np.testing.assert_array_equal(tables.pack_src[i], np.asarray(src))
        np.testing.assert_array_equal(tables.pack_valid[i], np.asarray(valid))
        rsrc, rvalid = variants.unpack_index_map_in_graph(
            jnp.asarray(rc[i], jnp.int32), jnp.asarray(rd[i], jnp.int32),
            p, cap, recv_rows)
        np.testing.assert_array_equal(tables.unpack_src[i], np.asarray(rsrc))
        np.testing.assert_array_equal(tables.unpack_valid[i], np.asarray(rvalid))


def test_empty_rounds_get_zero_capacity():
    """A ring-banded pattern produces capacity-0 (elidable) rounds exactly at
    the empty diagonals, and the active schedule excludes them."""
    p = 8
    c = np.zeros((p, p), np.int64)
    for i in range(p):
        c[i, i] = 4
        c[i, (i + 1) % p] = 3
        c[i, (i - 1) % p] = 2
    caps = md.ring_round_capacities(c)
    active = md.active_round_schedule(caps)
    np.testing.assert_array_equal(active, [1, p - 1])
    assert all(caps[r] == 0 for r in range(2, p - 1))
    assert caps[1] > 0 and caps[p - 1] > 0


def test_xor_round_capacities_use_xor_diagonal():
    """Pairwise-schedule capacities gate on c[i, i^r], not the ring diagonal."""
    p = 4
    c = np.zeros((p, p), np.int64)
    c[0, 3] = 40        # XOR round 3 (0^3=3); ring round 3 from rank 0 also 3
    c[2, 3] = 17        # XOR round 1 (2^1=3); ring round 1 from rank 2 is 3
    xor_caps = md.xor_round_capacities(c)
    ring_caps = md.ring_round_capacities(c)
    assert xor_caps[1] >= 17 and xor_caps[3] >= 40
    assert xor_caps[2] == 0
    # the ring schedule distributes the same cells differently
    assert ring_caps[1] >= 17 and ring_caps[3] >= 40


def test_hierarchy_locality_detection():
    p_outer, p_inner = 2, 4
    p = p_outer * p_inner
    c = np.zeros((p, p), np.int64)
    c[0:4, 0:4] = 5
    c[4:8, 4:8] = 3
    assert md.hierarchy_is_all_local(c, p_outer, p_inner)
    c[0, 5] = 1          # one cross-group row
    assert not md.hierarchy_is_all_local(c, p_outer, p_inner)


def test_signature_separates_compile_relevant_fields():
    """PlanCache key collision fix: lock_schedule / tile_rows / pack_impl /
    baked_metadata all reach the digest."""
    c = np.array([[1, 2], [3, 4]])
    base = dict(feature_shape=(4,), dtype="float32", variant="lock",
                axis=("x",), row_bytes=16)
    s0 = md.PatternSignature.build(c, **base)
    assert s0 == md.PatternSignature.build(c, **base)
    assert s0 != md.PatternSignature.build(c, **base, lock_schedule="pairwise")
    assert s0 != md.PatternSignature.build(c, **base, tile_rows=16)
    assert s0 != md.PatternSignature.build(c, **base, pack_impl="pallas")
    assert s0 != md.PatternSignature.build(c, **base, baked_metadata=False)
    # mesh factorizations share axis *names* but bake different schedules:
    # a (2, 4) and a (4, 2) grouped mesh must not share one cached plan
    s24 = md.PatternSignature.build(c, **base, axis_sizes=(2, 4))
    assert s24 != md.PatternSignature.build(c, **base, axis_sizes=(4, 2))
    assert s24 != s0


def test_signature_dtype_spelling_is_canonical():
    """jnp.float32 (a scalar class), "float32", and np.dtype("float32") are
    one pattern: the prewarm pipeline replays captured requests from JSON,
    so a spelling-sensitive digest would hide every prewarmed artifact."""
    c = np.array([[1, 2], [3, 4]])
    base = dict(feature_shape=(4,), variant="fence", axis=("x",),
                row_bytes=16, axis_sizes=(2,))
    sigs = {md.PatternSignature.build(c, dtype=d, **base)
            for d in (jnp.float32, "float32", np.dtype("float32"), np.float32)}
    assert len(sigs) == 1
    assert sigs.pop().dtype == "float32"


def test_window_cache_free_drops_every_pipelined_slot():
    """Regression: WindowCache.free() used to drop only slot 0, so the
    extra buffers a depth>1 pipelined run materialized stayed alive on
    device after the cache was freed."""
    from repro.core import AlltoallvSpec, PlanCache
    from repro.launch.mesh import make_host_mesh

    cache = PlanCache()
    spec = AlltoallvSpec(send_counts=np.array([[24]]), feature_shape=(4,),
                         dtype=jnp.float32, axis=("x",))
    plan = cache.get(spec, make_host_mesh(1))
    x = jax.device_put(jnp.zeros(plan.global_send_shape, jnp.float32),
                       plan._x_sharding)
    for _ in range(4):
        plan.wait(plan.start_pipelined(x, depth=4))
    assert len(plan.window._slots) == 4
    cache.window_cache.free()
    assert len(plan.window._slots) == 0           # every slot, not just #0

    # plan.free() after a fresh depth-4 run also drops every slot
    for _ in range(4):
        plan.wait(plan.start_pipelined(x, depth=4))
    assert len(plan.window._slots) == 4
    plan.free()
    assert len(plan.window._slots) == 0
