"""Per-arch smoke tests: every assigned architecture instantiates at reduced
scale and runs one forward + one train step on CPU — shapes + finiteness.
(The FULL configs are exercised via the dry-run only.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.data.pipeline import DataPipeline
from repro.models import api as model_api

SEQ = 64
BATCH = 2


def _batch_for(cfg):
    pipe = DataPipeline(cfg, SEQ, BATCH, mesh=None, seed=7)
    return pipe.batch_at(0)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke(arch):
    cfg = get_reduced(arch)
    params, specs = model_api.init_model(jax.random.key(0), cfg)

    # logical specs mirror the params tree exactly
    assert jax.tree.structure(jax.tree.map(lambda x: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, specs,
                                        is_leaf=lambda s: isinstance(s, tuple)))

    batch = _batch_for(cfg)
    loss, metrics = model_api.model_loss(params, cfg, batch)
    assert np.isfinite(float(loss)), (arch, metrics)
    assert 1.0 < float(loss) < 20.0, f"{arch}: implausible initial loss {loss}"

    grads = jax.grad(lambda p: model_api.model_loss(p, cfg, batch)[0])(params)
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves), arch
    # at least one nonzero gradient per arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in gleaves), arch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_abstract_init_matches(arch):
    """abstract=True produces the same tree/shapes/dtypes as real init."""
    cfg = get_reduced(arch)
    real, _ = model_api.init_model(jax.random.key(0), cfg)
    abst, _ = model_api.init_model(None, cfg, abstract=True)
    rf = jax.tree_util.tree_flatten_with_path(real)[0]
    af = jax.tree_util.tree_flatten_with_path(abst)[0]
    assert len(rf) == len(af)
    for (pr, r), (pa, a) in zip(rf, af):
        assert pr == pa
        assert r.shape == a.shape and r.dtype == a.dtype, (pr, r.shape, a.shape)


def test_full_config_param_counts():
    """Config arithmetic sanity for the full-size models (no allocation)."""
    from repro.configs import get
    from repro.roofline.analyze import count_params

    expect = {
        "deepseek-67b": (67e9, 69e9),
        "nemotron-4-15b": (15e9, 16.5e9),
        "minicpm-2b": (2.4e9, 3.0e9),
        "olmo-1b": (1.0e9, 1.4e9),
        "internvl2-26b": (19e9, 21e9),    # LM backbone (ViT is stubbed)
        "olmoe-1b-7b": (6.5e9, 7.5e9),
        "xlstm-125m": (0.08e9, 0.17e9),
        "whisper-base": (0.06e9, 0.09e9),
    }
    for arch, (lo, hi) in expect.items():
        total, active = count_params(get(arch))
        assert lo <= total <= hi, f"{arch}: {total/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
        assert active <= total


def test_moe_active_params_less_than_total():
    from repro.configs import get
    from repro.roofline.analyze import count_params

    for arch in ("olmoe-1b-7b", "moonshot-v1-16b-a3b", "jamba-v0.1-52b"):
        total, active = count_params(get(arch))
        assert active < total * 0.6, f"{arch} active {active/1e9:.1f}B vs {total/1e9:.1f}B"
