"""Quantized gradient compression: single-device property tests
(distributed behavior covered in test_distributed.py)."""

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, strategies as st

from repro.parallel import compression


@settings(max_examples=30)
@given(st.integers(0, 10_000), st.floats(1e-6, 1e3))
def test_quantize_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    q, s = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, s)
    step = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(back - x))) <= step / 2 + 1e-9
    assert q.dtype == jnp.int8


def test_error_feedback_removes_bias():
    """Repeatedly compressing the same gradient with error feedback must
    deliver the exact value in aggregate (bias-free in the long run)."""
    g = jnp.asarray(np.random.default_rng(0).standard_normal(64), jnp.float32)
    err = jnp.zeros_like(g)
    delivered = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        x = g + err
        q, s = compression.quantize_int8(x)
        deq = compression.dequantize_int8(q, s)
        err = x - deq
        delivered = delivered + deq
    np.testing.assert_allclose(np.asarray(delivered / n), np.asarray(g),
                               atol=float(jnp.max(jnp.abs(g))) / 127.0)


def test_zero_gradient():
    q, s = compression.quantize_int8(jnp.zeros(16))
    assert float(jnp.max(jnp.abs(compression.dequantize_int8(q, s)))) == 0.0
