"""Logical-axis resolution unit tests (divisibility, conflicts, profiles)."""

import dataclasses

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.parallel.sharding import (DECODE_RULES, DEFAULT_RULES,
                                     LONG_CONTEXT_RULES, axis_rules, resolve)


@dataclasses.dataclass
class FakeMesh:
    """Axis-name/shape stub — resolve() never touches devices."""
    shape: dict
    @property
    def axis_names(self):
        return tuple(self.shape)


def make_fake(shape, axes):
    return FakeMesh(dict(zip(axes, shape)))


def test_resolve_basic():
    mesh = make_fake((2, 2), ("data", "model"))
    with axis_rules(DEFAULT_RULES, mesh):
        assert resolve(("batch", "seq", "embed")) == P("data")
        assert resolve(("embed", "ff")) == P(None, "model")
        assert resolve(("vocab", "embed")) == P("model")


def test_resolve_skips_trivial_axes():
    mesh = make_fake((1, 1), ("data", "model"))
    with axis_rules(DEFAULT_RULES, mesh):
        assert resolve(("batch", "seq", "embed")) == P()
        assert resolve(("embed", "ff")) == P()


def test_resolve_divisibility_drops():
    mesh = make_fake((1, 2), ("data", "model"))
    with axis_rules(DEFAULT_RULES, mesh):
        # kv_heads=3 can't split model=2 -> dropped
        assert resolve(("embed", "kv_heads", "head_dim"), (64, 3, 16)) == P()
        assert resolve(("embed", "kv_heads", "head_dim"), (64, 4, 16)) == \
            P(None, "model")


def test_resolve_axis_conflict_first_wins():
    mesh = make_fake((2, 2), ("data", "model"))
    with axis_rules(DECODE_RULES, mesh):
        # decode rules: seq takes the model axis; heads loses it
        spec = resolve(("batch", "seq", "kv_heads", "head_dim"),
                       (4, 128, 8, 32))
        assert spec == P("data", "model")
        # seq=1 undividable -> heads gets the axis back
        spec = resolve(("batch", "seq", "heads", "head_dim"), (4, 1, 8, 32))
        assert spec == P("data", None, "model")


def test_long_context_rules():
    mesh = make_fake((2, 2, 2), ("pod", "data", "model"))
    with axis_rules(LONG_CONTEXT_RULES, mesh):
        # batch replicated, seq -> data
        assert resolve(("batch", "seq", "kv_heads", "head_dim"),
                       (1, 1024, 8, 32)) == P(None, "data", "model")


def test_multi_axis_batch():
    mesh = make_fake((2, 2, 2), ("pod", "data", "model"))
    with axis_rules(DEFAULT_RULES, mesh):
        spec = resolve(("batch", "seq"), (8, 64))
        assert spec == P(("pod", "data"))
        # batch=2 divides pod only
        spec = resolve(("batch", "seq"), (2, 64))
        assert spec == P("pod")


def test_param_factory_records_specs():
    from repro.parallel.sharding import ParamFactory, normal_init

    f = ParamFactory(jax.random.key(0), dtype=np.float32)
    f.param("a/w", (4, 8), ("embed", "ff"), normal_init(1.0))
    f.param("a/b", (8,), ("ff",), normal_init(1.0))
    assert f.params["a"]["w"].shape == (4, 8)
    assert f.logical_specs["a"]["w"] == ("embed", "ff")
    # abstract mode: same tree, ShapeDtypeStructs
    fa = ParamFactory(None, dtype=np.float32, abstract=True)
    fa.param("a/w", (4, 8), ("embed", "ff"), normal_init(1.0))
    assert isinstance(fa.params["a"]["w"], jax.ShapeDtypeStruct)
