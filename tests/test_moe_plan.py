"""Single-device halves of the plan-backed MoE dispatch rework: embedded
plan semantics, identity-map detection, chunk-geometry clamping, and EP-axis
derivation from the sharding rules.  Multi-device output identity lives in
test_distributed.py (moe_plan_backed_parity / moe_overlap_invariance /
moe_planstore_warm_start)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import MoEConfig
from repro.core import alltoallv_init, metadata as md
from repro.launch.mesh import make_host_mesh, make_mesh
from repro.models import moe as moe_mod
from repro.parallel.sharding import HIER_EP_RULES, axis_rules


def test_identity_maps_detected_for_uniform_pattern():
    """A uniform all-equal tile-aligned counts matrix (the MoE bucket
    layout) has identity pack/unpack maps; a ragged one does not."""
    mesh = make_host_mesh(1)
    plan = alltoallv_init(np.full((1, 1), 8), (4,), jnp.float32, mesh,
                          axis="x")
    assert plan.identity_maps
    ragged = alltoallv_init(np.full((1, 1), 5), (4,), jnp.float32, mesh,
                            axis="x")
    assert not ragged.identity_maps


def test_embed_matches_standalone_start():
    """The embedded epoch body produces the same recv buffer as the
    standalone START path (here on a 1-device mesh; multi-device parity is
    the dist cases' job)."""
    mesh = make_host_mesh(1)
    counts = np.array([[5]])
    plan = alltoallv_init(counts, (4,), jnp.float32, mesh, axis="x")
    x = jnp.arange(plan.global_send_shape[0] * 4, dtype=jnp.float32
                   ).reshape(plan.global_send_shape)
    want = np.asarray(plan.wait(plan.start(x)))
    fn = shard_map(plan.embed(), mesh=mesh, in_specs=P("x"),
                   out_specs=P("x"), check_vma=False)
    got = np.asarray(jax.jit(fn)(x))
    n = int(counts.sum())
    np.testing.assert_array_equal(got[:n], want[:n])
    # embedded path zeroes padding instead of window write-through
    assert not np.abs(got[n:]).any()


def test_embed_rejects_unembeddable_specs():
    mesh = make_host_mesh(1)
    plan = alltoallv_init(np.full((1, 1), 8), (4,), jnp.float32, mesh,
                          axis="x", baked_metadata=False)
    with pytest.raises(ValueError, match="baked_metadata"):
        plan.embed()


def test_overlap_depth_clamps_to_capacity_geometry():
    """Requested depths that do not partition the capacity cleanly clamp to
    the largest feasible divisor; the backing plan (when built) always has
    the chunk geometry."""
    moe = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=8.0)
    # mesh=None -> ep=1, table-free, but geometry fields still computed
    p1 = moe_mod.MoEDispatchPlan.build(
        dataclasses.replace(moe, overlap_chunks=4), 128, None)
    assert p1.capacity % p1.overlap_chunks == 0
    assert p1.chunk_capacity * p1.overlap_chunks == p1.capacity
    # a prime-ish capacity: depth 7 request on cap that 7 does not divide
    p2 = moe_mod.MoEDispatchPlan.build(moe, 128, None, overlap_chunks=7)
    assert p2.capacity % p2.overlap_chunks == 0
    assert (p2.e_local * p2.chunk_capacity) % 8 == 0


def test_auto_variant_resolves_when_no_ep_exchange():
    """a2a_variant='auto' with nothing to tune (ep == 1, or a dispatch that
    never runs the a2a) quietly resolves to the dense-uniform default; the
    must-be-plan-backed error is reserved for a real persistent EP exchange
    (covered by dist_cases.moe_planstore_warm_start on 8 devices)."""
    moe = MoEConfig(n_experts=8, top_k=2, d_expert=16, a2a_variant="auto")
    mesh = make_host_mesh(1, axis="model")
    plan = moe_mod.MoEDispatchPlan.build(moe, 64, mesh, plan_backed=False)
    assert plan.variant == "fence" and not plan.plan_backed
    gs = dataclasses.replace(moe, dispatch="gspmd")
    plan = moe_mod.MoEDispatchPlan.build(gs, 64, mesh, d_model=32)
    assert plan.variant == "fence" and not plan.plan_backed


def test_ep_axes_follow_experts_rule():
    """The dispatch plan derives its EP axis (or pair) from the active
    ``experts`` sharding rule — HIER_EP_RULES yields the (pod, model) pair
    without any hier_axes override."""
    mesh = make_mesh((1, 1), ("data", "model"))
    moe = MoEConfig(n_experts=8, top_k=2, d_expert=16)
    # size-1 axes are dropped: no EP
    plan = moe_mod.MoEDispatchPlan.build(moe, 64, mesh)
    assert plan.axis is None and plan.ep_size == 1 and not plan.plan_backed
    with axis_rules(HIER_EP_RULES, mesh):
        # still size-1 -> no EP even under the widened rule
        plan = moe_mod.MoEDispatchPlan.build(moe, 64, mesh)
        assert plan.axis is None and plan.hier_axes is None


def test_plan_backed_counts_are_chunk_geometry():
    """The backing pattern is the uniform chunk-peer-rows matrix, so the
    plan-store signature keys on the pipeline depth."""
    moe = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=8.0,
                    dispatch="persistent_a2a")
    mesh = make_host_mesh(1, axis="model")
    # ep == 1 on one device: no backing plan regardless of d_model
    plan = moe_mod.MoEDispatchPlan.build(moe, 64, mesh, d_model=32,
                                         dtype=jnp.float32)
    assert not plan.plan_backed
    # geometry invariants hold anyway
    assert plan.peer_rows == plan.e_local * plan.capacity
    assert plan.chunk_peer_rows * plan.overlap_chunks == plan.peer_rows
