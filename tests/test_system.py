"""End-to-end system behaviour: the full train CLI and serve CLI run on a
reduced architecture, checkpoint, resume, and generate."""

import os
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run(args, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert r.returncode == 0, f"{args} failed:\n{r.stdout[-2000:]}\n{r.stderr[-3000:]}"
    return r.stdout


def test_train_cli_end_to_end():
    with tempfile.TemporaryDirectory() as d:
        out = _run(["repro.launch.train", "--arch", "olmoe-1b-7b", "--reduced",
                    "--steps", "6", "--seq-len", "128", "--global-batch", "4",
                    "--ckpt-dir", d, "--ckpt-every", "3", "--log-every", "2"])
        assert "train finished" in out and "'final_step': 6" in out
        assert any(p.startswith("step_") for p in os.listdir(d))

        # resume continues from the checkpoint
        out = _run(["repro.launch.train", "--arch", "olmoe-1b-7b", "--reduced",
                    "--steps", "8", "--seq-len", "128", "--global-batch", "4",
                    "--ckpt-dir", d, "--ckpt-every", "4", "--log-every", "2"])
        assert "'final_step': 8" in out


def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "olmo-1b", "--reduced",
                "--batch", "2", "--prompt-len", "16", "--tokens", "4"])
    assert "generated (2, 4)" in out


def test_train_cli_dispatch_override():
    out = _run(["repro.launch.train", "--arch", "olmoe-1b-7b", "--reduced",
                "--steps", "2", "--seq-len", "64", "--global-batch", "2",
                "--dispatch", "nonpersistent_a2a", "--a2a-variant", "lock",
                "--log-every", "1"])
    assert "'final_step': 2" in out
