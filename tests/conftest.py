"""Test configuration.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
Multi-device correctness runs through subprocesses (helpers.run_case), which
set the fake-device count before jax initializes.
"""

import os
import subprocess
import sys

import pytest

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    # Bare environment: tests fall back to tests/_hypothesis_compat.py's
    # deterministic sampler; there is no profile to register.
    pass
else:
    settings.register_profile(
        "repro", deadline=None, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
    settings.load_profile("repro")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def run_case(case: str, devices: int = 8, timeout: int = 900) -> str:
    """Run one repro.testing.dist_cases case in a subprocess."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "repro.testing.dist_cases", case,
         "--devices", str(devices)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT)
    if r.returncode != 0 or f"CASE_OK {case}" not in r.stdout:
        raise AssertionError(
            f"dist case {case} failed:\nSTDOUT:\n{r.stdout[-3000:]}\n"
            f"STDERR:\n{r.stderr[-5000:]}")
    return r.stdout


@pytest.fixture(scope="session")
def dist():
    return run_case
