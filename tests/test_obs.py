"""Unit tests for the unified observability layer (repro.obs) and the
telemetry extensions under it: span buffer/tracer semantics, trace export
+ structural validation, Prometheus rendering, break-even residuals,
thread-safe counters, per-rank rings, and the ``core.init_stats()``
snapshot/diff contract across PlanCache reuse and ``reset()``."""

import json
import math
import threading
import urllib.request

import numpy as np
import pytest

from repro.core import EXEC_TELEMETRY, INIT_STATS, EpochRing
from repro.obs import (TRACER, SpanBuffer, TraceValidationError,
                       breakeven_residual, check_breakeven, chrome_trace,
                       render_metrics, validate_trace, write_jsonl,
                       write_trace)
from repro.obs.spans import COMPLETE, INSTANT


@pytest.fixture(autouse=True)
def _clean_tracer():
    TRACER.reset()
    yield
    TRACER.reset()


# --- spans -------------------------------------------------------------------

def test_tracer_disabled_is_noop():
    assert not TRACER.enabled
    ctx = TRACER.span("x", "init", a=1)
    with ctx:
        pass
    TRACER.instant("y", "runtime")
    TRACER.emit_span("z", "execute", 0.0, 1.0)
    assert TRACER.snapshot()["records"] == []
    # the disabled context is a shared singleton: zero allocation per call
    assert TRACER.span("a", "init") is TRACER.span("b", "store")


def test_span_records_args_and_outcome_mutation():
    TRACER.enable()
    with TRACER.span("store_get", "store", backend="/s") as sp:
        sp.args["result"] = "hit"
    (rec,) = TRACER.snapshot()["records"]
    name, cat, ph, ts, dur, tid, args = rec
    assert (name, cat, ph) == ("store_get", "store", COMPLETE)
    assert args == {"backend": "/s", "result": "hit"}
    assert dur >= 0 and tid == threading.get_ident()


def test_span_records_exception_as_error_arg():
    TRACER.enable()
    with pytest.raises(ValueError):
        with TRACER.span("bake", "init.bake"):
            raise ValueError("boom")
    (rec,) = TRACER.snapshot()["records"]
    assert "boom" in rec[6]["error"]


def test_instant_and_emit_span():
    TRACER.enable()
    TRACER.instant("swap", "runtime", old="a", new="b")
    TRACER.emit_span("epoch", "execute", 1.0, 1.5, {"digest": "d"})
    recs = TRACER.snapshot()["records"]
    phases = {r[0]: r[2] for r in recs}
    assert phases == {"swap": INSTANT, "epoch": COMPLETE}
    epoch = next(r for r in recs if r[0] == "epoch")
    assert epoch[4] == pytest.approx(0.5)


def test_span_buffer_ring_overwrites_oldest():
    buf = SpanBuffer(capacity=8)
    for i in range(20):
        buf.emit(("s", "execute", COMPLETE, float(i), 0.0, 1, None))
    assert buf.count == 8
    kept = [r[3] for r in buf.snapshot()]
    assert kept == [float(i) for i in range(12, 20)]


def test_span_buffer_concurrent_writers_never_tear():
    buf = SpanBuffer(capacity=64)
    n_threads, per = 8, 500

    def w(k):
        for i in range(per):
            buf.emit(("s", "execute", COMPLETE, float(i), 0.0, k, None))

    ts = [threading.Thread(target=w, args=(k,)) for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    recs = buf.snapshot()
    assert len(recs) == 64
    assert all(len(r) == 7 for r in recs)       # no torn records


def test_tracer_thread_names_registered():
    TRACER.enable()

    def w():
        TRACER.instant("bg", "runtime")

    t = threading.Thread(target=w, name="repro-replan")
    t.start()
    t.join()
    TRACER.instant("fg", "runtime")
    names = TRACER.snapshot()["thread_names"]
    assert "repro-replan" in names.values()
    assert len(names) >= 2


# --- trace export + validation ----------------------------------------------

def _span(name, cat, ts_us, dur_us, tid=1, args=None):
    return {"name": name, "cat": cat, "ph": "X", "pid": 1, "tid": tid,
            "ts": ts_us, "dur": dur_us, "args": args or {}}


def test_chrome_trace_structure_and_units():
    TRACER.enable()
    TRACER.emit_span("epoch", "execute", 0.001, 0.003, {"digest": "d"})
    TRACER.instant("swap", "runtime")
    trace = chrome_trace()
    evs = trace["traceEvents"]
    assert evs[0] == {"name": "process_name", "ph": "M", "pid": evs[0]["pid"],
                      "tid": 0, "args": {"name": "repro-driver"}}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["dur"] == pytest.approx(2000.0)     # seconds -> microseconds
    i = next(e for e in evs if e["ph"] == "i")
    assert i["s"] == "t"
    assert trace["displayTimeUnit"] == "ms"


def test_validate_trace_accepts_nested_spans():
    trace = {"traceEvents": [
        _span("plan_init", "init", 0, 100, args={"warm": False}),
        _span("index_table_bake", "init.bake", 10, 20),
        _span("measure_bursts", "init.autotune", 40, 50),
        _span("epoch", "execute", 200, 10),
    ]}
    s = validate_trace(trace, expect_cats=("init", "execute"))
    assert s["events"] == 4 and s["cold_inits"] == 1 and s["warm_inits"] == 0


def test_validate_trace_rejects_partial_overlap():
    trace = {"traceEvents": [
        _span("a", "execute", 0, 100),
        _span("b", "execute", 50, 100),        # spills past a's end
    ]}
    with pytest.raises(TraceValidationError, match="overlaps"):
        validate_trace(trace)


def test_validate_trace_store_spans_exempt_from_nesting():
    # CAS-merge retries legitimately produce overlapping store timings.
    trace = {"traceEvents": [
        _span("store_merge", "store", 0, 100),
        _span("store_put", "store", 50, 100),
    ]}
    validate_trace(trace)


def test_validate_trace_warm_init_with_bake_child_fails():
    trace = {"traceEvents": [
        _span("plan_init", "init", 0, 100, args={"warm": True}),
        _span("index_table_bake", "init.bake", 10, 20),
    ]}
    with pytest.raises(TraceValidationError, match="warm-start contract"):
        validate_trace(trace)


def test_validate_trace_missing_expected_category_fails():
    trace = {"traceEvents": [_span("epoch", "execute", 0, 10)]}
    with pytest.raises(TraceValidationError, match="expected category"):
        validate_trace(trace, expect_cats=("runtime",))


def test_validate_trace_malformed_inputs(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(TraceValidationError, match="not valid JSON"):
        validate_trace(str(bad))
    with pytest.raises(TraceValidationError, match="traceEvents"):
        validate_trace({"other": []})
    with pytest.raises(TraceValidationError, match="missing/negative dur"):
        validate_trace({"traceEvents": [
            {"name": "a", "cat": "x", "ph": "X", "pid": 1, "tid": 1,
             "ts": 0, "dur": -1}]})
    with pytest.raises(TraceValidationError, match="unknown phase"):
        validate_trace({"traceEvents": [
            {"name": "a", "ph": "B", "pid": 1, "tid": 1, "ts": 0}]})


def test_write_trace_and_jsonl_roundtrip(tmp_path):
    import time

    TRACER.enable()
    t1 = time.perf_counter()
    TRACER.emit_span("epoch", "execute", t1 - 0.5, t1, {"digest": "d"})
    p = tmp_path / "t.json"
    trace = write_trace(str(p))
    assert validate_trace(str(p))["events"] == 1
    assert json.loads(p.read_text()) == json.loads(json.dumps(trace))
    lp = tmp_path / "t.jsonl"
    assert write_jsonl(str(lp)) == 1
    rec = json.loads(lp.read_text().splitlines()[0])
    assert rec["name"] == "epoch" and rec["dur_s"] == pytest.approx(0.5)
    # time_unix maps the span back to wall time via origin_unix
    assert abs(rec["time_unix"] - time.time()) < 60.0


# --- epoch rings + exec telemetry -------------------------------------------

def test_epoch_ring_summary_has_tail_quantiles():
    ring = EpochRing(capacity=128)
    for v in np.linspace(0.001, 0.1, 100):
        ring.record(float(v))
    s = ring.summary()
    assert s["p50_s"] <= s["p95_s"] <= s["p99_s"] <= s["max_s"]
    assert s["p95_s"] == pytest.approx(
        float(np.percentile(np.linspace(0.001, 0.1, 100), 95)))


def test_exec_telemetry_rank_rings_and_summary():
    tel = type(EXEC_TELEMETRY)()        # fresh instance, not the singleton
    for e in range(6):
        for r in range(4):
            tel.record_rank("d1", r, 0.001 * (r + 1))
    rs = tel.rank_summary("d1")
    assert sorted(rs) == [0, 1, 2, 3]
    assert rs[3]["p50_s"] == pytest.approx(0.004)
    assert rs[0]["count"] == 6
    assert tel.rank_summary("other") == {}
    snap = tel.snapshot()
    assert ("d1", 3) in snap["ranks"]
    tel.reset()
    assert tel.rank_summary("d1") == {} and tel.snapshot()["ranks"] == {}


def test_exec_telemetry_snapshot_safe_under_concurrent_mutation():
    tel = type(EXEC_TELEMETRY)()
    stop = threading.Event()
    errors = []

    def mutate():
        i = 0
        while not stop.is_set():
            tel.record(f"d{i % 50}", 0.001)
            tel.record_rank(f"d{i % 50}", i % 8, 0.001)
            tel.record_swap(old="a", new="b", reason={"kind": "t"})
            i += 1

    def read():
        try:
            for _ in range(200):
                snap = tel.snapshot()
                for s in snap["plans"].values():
                    assert s["count"] >= 0
        except Exception as e:      # noqa: BLE001 — the assertion IS the test
            errors.append(e)

    w = threading.Thread(target=mutate)
    r = threading.Thread(target=read)
    w.start(); r.start()
    r.join(); stop.set(); w.join()
    assert errors == []


# --- init stats (satellite: snapshot/diff across PlanCache reuse) -----------

def test_init_stats_bump_is_thread_safe():
    INIT_STATS.reset()
    n_threads, per = 8, 1000

    def w():
        for _ in range(per):
            INIT_STATS.bump("table_bakes")

    ts = [threading.Thread(target=w) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert INIT_STATS.table_bakes == n_threads * per
    INIT_STATS.reset()


def test_init_stats_snapshot_diff_across_plancache_reuse():
    """init_stats() snapshots diff cleanly around INIT work: a first build
    pays bakes, an in-cache rebuild of the same spec pays nothing, and
    reset() rebaselines to all-zero."""
    import jax.numpy as jnp

    from repro.core import PlanCache, alltoallv_init, init_stats, \
        reset_init_stats
    from repro.launch.mesh import make_host_mesh

    reset_init_stats()
    base = init_stats()
    assert set(base) >= {"cold_inits", "warm_inits", "table_bakes",
                         "store_hits"}
    assert all(v == 0 for v in base.values())

    mesh = make_host_mesh(1)
    cache = PlanCache()
    counts = np.full((1, 1), 8)
    alltoallv_init(counts, (4,), jnp.float32, mesh, axis="x",
                   variant="fence", cache=cache)
    after_cold = init_stats()
    diff = {k: after_cold[k] - base[k] for k in base}
    assert diff["cold_inits"] == 1 and diff["table_bakes"] >= 1
    assert diff["warm_inits"] == 0

    # Same spec through the same cache: a pure cache hit does no INIT work.
    alltoallv_init(counts, (4,), jnp.float32, mesh, axis="x",
                   variant="fence", cache=cache)
    after_reuse = init_stats()
    assert after_reuse == after_cold, (after_cold, after_reuse)

    # A fresh cache re-pays the bake (no store configured to warm from).
    alltoallv_init(counts, (4,), jnp.float32, mesh, axis="x",
                   variant="fence", cache=PlanCache())
    assert init_stats()["cold_inits"] == after_reuse["cold_inits"] + 1

    reset_init_stats()
    assert all(v == 0 for v in init_stats().values())


# --- break-even --------------------------------------------------------------

def test_breakeven_residual_math():
    fit = {"t_best": 0.010, "t_second": 0.012, "sweep_seconds": 0.1}
    assert breakeven_residual(fit, 0.010) == pytest.approx(0.0)
    assert breakeven_residual(fit, 0.011) == pytest.approx(0.1)
    assert breakeven_residual({"t_best": 0.0}, 0.01) == math.inf


def test_check_breakeven_gates_on_warmup_and_reports_n_observed():
    snap = {"fits": {"d1": {"t_best": 0.010, "t_second": 0.012,
                            "sweep_seconds": 0.1, "n_amortize": 50},
                     "d2": {"t_best": 0.010, "t_second": 0.012,
                            "sweep_seconds": 0.1}},
            "plans": {"d1": {"count": 20, "p50_s": 0.011},
                      "d2": {"count": 2, "p50_s": 0.011}},    # <= warmup
            "swaps": [], "ranks": {}}
    out = check_breakeven(snap)
    assert [r["digest"] for r in out] == ["d1"]
    r = out[0]
    assert r["residual"] == pytest.approx(0.1)
    assert r["n_observed"] == math.ceil(0.1 / (0.012 - 0.011))
    assert r["n_amortize"] == 50


def test_check_breakeven_no_positive_margin_no_n_observed():
    snap = {"fits": {"d": {"t_best": 0.010, "t_second": 0.012,
                           "sweep_seconds": 0.1}},
            "plans": {"d": {"count": 9, "p50_s": 0.013}}}    # worse than 2nd
    (r,) = check_breakeven(snap)
    assert r["n_observed"] is None and r["residual"] == pytest.approx(0.3)


# --- metrics -----------------------------------------------------------------

def _fake_snapshots():
    init = {"cold_inits": 2, "warm_inits": 3, "table_bakes": 4,
            "autotune_sweeps": 1, "autotune_bursts": 18, "store_hits": 3,
            "store_misses": 1, "store_puts": 2, "store_invalid": 0}
    ex = {"plans": {"abc": {"count": 10, "mean_s": 0.01, "p50_s": 0.01,
                            "p95_s": 0.02, "p99_s": 0.03, "max_s": 0.03,
                            "last_s": 0.01}},
          "ranks": {("abc", 0): {"count": 10, "p50_s": 0.009},
                    ("abc", 1): {"count": 10, "p50_s": 0.013}},
          "swaps": [{"old": "x", "new": "abc"}],
          "fits": {"abc": {"t_best": 0.01, "t_second": 0.012,
                           "sweep_seconds": 0.5, "n_amortize": 250}}}
    return ex, init


def test_render_metrics_exposition():
    ex, init = _fake_snapshots()
    text = render_metrics(exec_snapshot=ex, init_snapshot=init)
    assert 'repro_init_total{kind="warm"} 3' in text
    assert 'repro_init_total{kind="cold"} 2' in text
    assert "repro_table_bakes_total 4" in text
    assert 'repro_store_requests_total{result="hit"} 3' in text
    assert "repro_store_hit_ratio 0.750000" in text
    assert "repro_plan_swaps_total 1" in text
    assert 'repro_epoch_seconds{digest="abc",quantile="0.99"}' in text
    assert 'repro_epoch_seconds_count{digest="abc"} 10' in text
    assert 'repro_epoch_rank_seconds{digest="abc",rank="1"} 0.013' in text
    assert 'repro_breakeven_residual{digest="abc"} 0.000000' in text
    assert 'repro_breakeven_n_amortize{digest="abc"} 250' in text
    # every non-comment line is "name{labels} value" — scrapable
    for line in text.splitlines():
        if line and not line.startswith("#"):
            assert len(line.rsplit(" ", 1)) == 2, line


def test_metrics_server_serves_and_404s():
    from repro.obs import MetricsServer
    srv = MetricsServer(0).start()
    try:
        url = f"http://127.0.0.1:{srv.port}"
        body = urllib.request.urlopen(f"{url}/metrics").read().decode()
        assert "repro_init_total" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{url}/other")
    finally:
        srv.stop()


# --- CLI ---------------------------------------------------------------------

def test_obs_cli_trace_validate(tmp_path, capsys):
    from repro.obs.__main__ import main
    TRACER.enable()
    with TRACER.span("plan_init", "init", warm=False):
        with TRACER.span("index_table_bake", "init.bake"):
            pass
    TRACER.emit_span("epoch", "execute", 0.1, 0.2, {"digest": "d"})
    p = tmp_path / "trace.json"
    write_trace(str(p))

    assert main(["trace", str(p), "--validate", "--expect", "init",
                 "--expect", "execute"]) == 0
    assert "TRACE OK" in capsys.readouterr().out

    assert main(["trace", str(p), "--validate",
                 "--expect", "runtime"]) == 1
    assert "TRACE INVALID" in capsys.readouterr().err

    assert main(["report", "--trace", str(p)]) == 0
    out = capsys.readouterr().out
    assert "init.bake" in out and "execute" in out


def test_obs_cli_metrics_out(tmp_path, capsys):
    from repro.obs.__main__ import main
    p = tmp_path / "m.prom"
    assert main(["metrics", "--out", str(p)]) == 0
    assert "repro_init_total" in p.read_text()


# --- plan-level wiring --------------------------------------------------------

def test_plan_epoch_spans_and_record_epoch_anchor():
    """A plan's start() emits epoch spans when tracing is on, and
    record_epoch(t_end=...) anchors the backdated span exactly."""
    import jax
    import jax.numpy as jnp

    from repro.core import PlanCache, alltoallv_init
    from repro.launch.mesh import make_host_mesh

    EXEC_TELEMETRY.reset()
    mesh = make_host_mesh(1)
    plan = alltoallv_init(np.full((1, 1), 8), (4,), jnp.float32, mesh,
                          axis="x", variant="fence", cache=PlanCache())
    x = jax.device_put(jnp.zeros(plan.global_send_shape, jnp.float32),
                       plan._x_sharding)
    TRACER.enable()
    import time as _time

    jax.block_until_ready(plan.wait(plan.start(x)))
    t_end = _time.perf_counter()
    plan.record_epoch(0.25, t_end=t_end)
    recs = [r for r in TRACER.snapshot()["records"] if r[0] == "epoch"]
    assert len(recs) == 2
    anchored = max(recs, key=lambda r: r[3] + r[4])    # latest end = ours
    assert anchored[3] + anchored[4] == pytest.approx(t_end - TRACER._t0)
    assert anchored[4] == pytest.approx(0.25)
    assert anchored[6]["digest"] == plan.signature.digest
    ring = plan.epoch_ring
    assert ring.count == 2
