"""Plan-store unit tests: codec round-trips, corruption handling, version
invalidation, LRU eviction.

Everything here is host-side (numpy + files); the multi-device warm-start
identity checks live in test_distributed.py (planstore_warm_start).
"""

import os
import tempfile

import numpy as np
import pytest

from _hypothesis_compat import given, strategies as st
from repro.core import metadata as md
from repro.planstore import (ABSENT, ArtifactError, FsRemoteBackend,
                             GenerationConflict, LocalDirBackend, PlanArtifact,
                             PlanStore, RemoteUnavailable, SCHEMA_VERSION,
                             TieredPlanStore, codec, parse_store_url,
                             signature_meta, store_key)

counts_matrices = st.integers(2, 10).flatmap(
    lambda p: st.lists(
        st.lists(st.integers(0, 50), min_size=p, max_size=p),
        min_size=p, max_size=p).map(np.array))

hier_counts = st.integers(1, 4).flatmap(
    lambda p_inner: st.lists(
        st.lists(st.integers(0, 30), min_size=2 * p_inner, max_size=2 * p_inner),
        min_size=2 * p_inner, max_size=2 * p_inner).map(
            lambda rows: (np.array(rows), p_inner)))


def _sig(counts, variant="fence", axis=("x",), axis_sizes=None, **kw):
    p = counts.shape[0]
    return md.PatternSignature.build(
        counts, (4,), "float32", variant, axis, 16,
        axis_sizes=axis_sizes if axis_sizes is not None else (p,), **kw)


def _baked_artifact(counts):
    cap = md.global_capacity(counts)
    recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
    tables = md.baked_index_tables(counts, cap, recv_rows)
    sig = _sig(counts)
    return sig, PlanArtifact(signature=signature_meta(sig),
                             index_tables=tables), tables


@given(counts_matrices)
def test_baked_tables_roundtrip(counts):
    """signature -> save -> load under a fresh store handle -> identical
    plan tensors, bit for bit."""
    sig, art, tables = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_artifact(sig, art)
        got = PlanStore(d).get(sig)
        assert got is not None and got.payload_kind == "baked_tables"
        for name in ("pack_src", "pack_valid", "unpack_src", "unpack_valid"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got.index_tables, name)),
                getattr(tables, name))


@given(hier_counts)
def test_hier_schedule_roundtrip(counts_and_inner):
    counts, p_inner = counts_and_inner
    p = counts.shape[0]
    recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
    sched = md.hier_two_stage_schedule(counts, 2, p_inner, recv_rows)
    sig = _sig(counts, variant="fence_hierarchy", axis=("o", "i"),
               axis_sizes=(2, p_inner))
    art = PlanArtifact(signature=signature_meta(sig), hier_schedule=sched)
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_artifact(sig, art)
        got = PlanStore(d).get(sig).hier_schedule
        assert (got.p_outer, got.p_inner, got.n_macro, got.remote_needed,
                got.s1_cap, got.s2_caps, got.s2_offs, got.total_s2,
                got.s3_cap, got.round_perms, got.cross_group_puts) == (
            sched.p_outer, sched.p_inner, sched.n_macro, sched.remote_needed,
            sched.s1_cap, sched.s2_caps, sched.s2_offs, sched.total_s2,
            sched.s3_cap, sched.round_perms, sched.cross_group_puts)
        for a, b in zip(got.tables, sched.tables):
            np.testing.assert_array_equal(np.asarray(a), b)
        assert p == got.unpack_src.shape[0]


def test_auto_choice_roundtrip():
    counts = np.full((4, 4), 3)
    sig = _sig(counts, variant="auto")
    choice = {"variant": "lock", "times": {"fence": 1e-4, "lock": 5e-5}}
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_auto(sig, choice)
        assert PlanStore(d).get_auto(sig) == choice


def test_truncated_entry_is_miss_not_crash():
    counts = np.full((4, 4), 7)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        path = PlanStore(d).put_artifact(sig, art)
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) // 2)
        store = PlanStore(d)
        assert store.get(sig) is None
        assert store.invalid == 1
        assert not os.path.exists(path)        # bad entry removed
        # and the slot is reusable: a fresh put round-trips again
        store.put_artifact(sig, art)
        assert store.get(sig) is not None


def test_garbage_entry_is_miss_not_crash():
    counts = np.full((4, 4), 5)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        with open(store.path_for(sig), "wb") as f:
            f.write(os.urandom(512))
        assert store.get(sig) is None and store.invalid == 1


def test_jax_version_mismatch_falls_back_cold():
    """An entry written under another jax version is keyed differently, so
    the live store simply misses (cold INIT) — stale tables never load."""
    counts = np.full((4, 4), 9)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        other = PlanStore(d, jax_ver="9.9.9")
        other.put_artifact(sig, art)
        live = PlanStore(d)
        assert live.path_for(sig) != other.path_for(sig)
        assert live.get(sig) is None and live.misses == 1
        # other-version store still finds its own entry
        assert PlanStore(d, jax_ver="9.9.9").get(sig) is not None


@pytest.mark.parametrize("field", ["jax", "repro", "schema"])
def test_tampered_entry_fails_meta_validation(field):
    """Key collisions cannot happen through the API, but a hand-copied file
    at the right path must still be rejected by metadata validation."""
    counts = np.full((4, 4), 4)
    sig, art, _ = _baked_artifact(counts)
    if field == "jax":
        art.jax_version = "9.9.9"
    elif field == "repro":
        art.repro_version = "0.0.0-other"
    else:
        art.schema_version = SCHEMA_VERSION + 1
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        with open(store.path_for(sig), "wb") as f:   # bypass put_artifact
            codec.dump(art, f)
        assert store.get(sig) is None and store.invalid == 1


def test_pre_collective_artifact_loads_warm():
    """Migration: a schema-v3 artifact written before the ``collective``
    field existed (its signature echo lacks the key) must keep warm-starting
    alltoallv INITs — the validator fills in the implicit default instead of
    invalidating every deployed store."""
    counts = np.full((4, 4), 7)
    sig, art, tables = _baked_artifact(counts)
    assert sig.collective == "alltoallv"
    legacy_meta = dict(art.signature)
    assert legacy_meta.pop("collective") == "alltoallv"
    art.signature = legacy_meta
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        with open(store.path_for(sig), "wb") as f:   # bypass put_artifact
            codec.dump(art, f)
        got = store.get(sig)
        assert got is not None and store.invalid == 0   # warm, not a crash
        np.testing.assert_array_equal(
            np.asarray(got.index_tables.pack_src), tables.pack_src)
        assert got.summary()["collective"] == "alltoallv"


def test_collective_field_keys_and_validates():
    """allgatherv signatures never alias an alltoallv entry even when the
    expanded count matrices coincide: distinct digests and store keys, and
    a legacy (collective-less) artifact hand-copied under a gatherv key is
    rejected by the signature echo."""
    from repro.core import patterns

    counts = np.full(4, 16, np.int64)
    sc = patterns.as_matrix("allgatherv", counts)    # row-constant [4, 4]
    sig_a2a = _sig(sc)                               # alltoallv over same sc
    sig_ag = _sig(sc, collective="allgatherv")
    assert sig_a2a.digest != sig_ag.digest
    assert store_key(sig_a2a) != store_key(sig_ag)
    assert signature_meta(sig_ag)["collective"] == "allgatherv"

    _, art, _ = _baked_artifact(np.asarray(sc))
    # Echo sig_ag's meta but drop the collective key: the validator's
    # implicit default ("alltoallv") must then mismatch "allgatherv" — the
    # one field standing between a legacy file and the wrong family.
    forged = dict(signature_meta(sig_ag))
    forged.pop("collective")
    art.signature = forged
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        with open(store.path_for(sig_ag), "wb") as f:
            codec.dump(art, f)
        assert store.get(sig_ag) is None and store.invalid == 1


def test_backend_mismatch_falls_back_cold():
    """Auto decisions measured on one backend must not be served to another
    (CPU timings would pin the wrong variant for a TPU process)."""
    counts = np.full((4, 4), 9)
    sig = _sig(counts, variant="auto")
    choice = {"variant": "ragged", "times": {"ragged": 1e-5}}
    with tempfile.TemporaryDirectory() as d:
        tpu_store = PlanStore(d, backend="tpu")
        tpu_store.put_auto(sig, choice)
        live = PlanStore(d)                     # cpu on this host
        assert live.path_for(sig) != tpu_store.path_for(sig)
        assert live.get_auto(sig) is None
        # and each backend's store keeps its own decision intact
        assert PlanStore(d, backend="tpu").get_auto(sig) == choice


def test_axis_sizes_mismatch_is_a_different_key():
    counts = np.full((8, 8), 3)
    s24 = _sig(counts, variant="fence_hierarchy", axis=("o", "i"),
               axis_sizes=(2, 4))
    s42 = _sig(counts, variant="fence_hierarchy", axis=("o", "i"),
               axis_sizes=(4, 2))
    assert store_key(s24) != store_key(s42)
    with tempfile.TemporaryDirectory() as d:
        recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
        sched = md.hier_two_stage_schedule(counts, 2, 4, recv_rows)
        PlanStore(d).put_artifact(
            s24, PlanArtifact(signature=signature_meta(s24),
                              hier_schedule=sched))
        store = PlanStore(d)
        assert store.get(s42) is None          # (4,2) never sees (2,4) tables
        assert store.get(s24) is not None


def test_signature_tamper_rejected():
    """Same file renamed under another signature's key: the signature echo
    in the metadata does not match and validation treats it as a miss."""
    a = np.full((4, 4), 3)
    b = np.full((4, 4), 8)
    sig_a, art_a, _ = _baked_artifact(a)
    sig_b = _sig(b)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        src = store.put_artifact(sig_a, art_a)
        os.replace(src, store.path_for(sig_b))
        assert store.get(sig_b) is None and store.invalid == 1


def test_lru_eviction_bounds_entries():
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d, max_entries=3)
        sigs = []
        for i in range(5):
            counts = np.full((4, 4), i + 1)
            sig, art, _ = _baked_artifact(counts)
            sigs.append(sig)
            store.put_artifact(sig, art)
            # distinct mtimes even on coarse-clock filesystems
            os.utime(store.path_for(sig), (i, i))
        assert len(store.entries()) <= 3
        assert store.get(sigs[0]) is None      # oldest evicted
        assert store.get(sigs[-1]) is not None  # newest kept


def test_stale_tmp_files_swept_on_put():
    """Staging files orphaned by killed writers get cleaned up by later
    puts; a fresh (in-flight) tmp file is left alone."""
    counts = np.full((4, 4), 6)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        stale = os.path.join(d, "tmp-999-deadbeef.plan")
        fresh = os.path.join(d, "tmp-999-cafef00d.plan")
        for p in (stale, fresh):
            with open(p, "wb") as f:
                f.write(b"partial write")
        os.utime(stale, (0, 0))                 # ancient
        store.put_artifact(sig, art)            # triggers the sweep
        assert not os.path.exists(stale)
        assert os.path.exists(fresh)


def test_attach_breakeven_merges_into_entry():
    counts = np.full((4, 4), 6)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        store.put_artifact(sig, art)
        store.attach_breakeven(sig, {"t_init": 1e-3, "t_persist": 2e-5,
                                     "t_mpi": 5e-5, "n_breakeven": 34})
        got = store.get(sig)
        assert got.breakeven["n_breakeven"] == 34
        assert got.index_tables is not None    # tables survived the merge


def test_dumps_loads_bytes_roundtrip():
    counts = np.full((4, 4), 2)
    _, art, tables = _baked_artifact(counts)
    got = codec.loads(codec.dumps(art))
    np.testing.assert_array_equal(got.index_tables.pack_src, tables.pack_src)


def test_empty_and_meta_only_artifacts():
    counts = np.zeros((4, 4), np.int64)
    sig = _sig(counts, variant="auto")
    art = PlanArtifact(signature=signature_meta(sig),
                       auto_choice={"variant": "fence", "times": {}})
    assert art.payload_kind == "meta_only"
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_artifact(sig, art)
        got = PlanStore(d).get(sig)
        assert got.index_tables is None and got.hier_schedule is None


def _hammer_store(args):
    """Worker for the concurrency test: alternate puts and gets of the same
    entry; return how many valid loads and how many misses were observed."""
    root, seed, rounds = args
    rng = np.random.default_rng(seed)
    counts = np.full((4, 4), 11)           # same signature for every worker
    sig, art, tables = _baked_artifact(counts)
    store = PlanStore(root)
    loads = misses = 0
    for _ in range(rounds):
        if rng.random() < 0.5:
            store.put_artifact(sig, art)
        got = store.get(sig)
        if got is None:
            misses += 1
        else:
            loads += 1
            np.testing.assert_array_equal(
                np.asarray(got.index_tables.pack_src), tables.pack_src)
    return loads, misses


def test_concurrent_writers_never_corrupt():
    """Many processes hammering one key: every successful read decodes to
    the exact tables (torn writes would fail decode; decode failures would
    delete the entry and show up as misses after the first put)."""
    import multiprocessing as mp

    with tempfile.TemporaryDirectory() as d:
        with mp.get_context("spawn").Pool(4) as pool:
            results = pool.map(_hammer_store,
                               [(d, seed, 20) for seed in range(4)])
        total_loads = sum(r[0] for r in results)
        assert total_loads > 0
        # the entry left behind is itself valid
        counts = np.full((4, 4), 11)
        sig, _, tables = _baked_artifact(counts)
        final = PlanStore(d).get(sig)
        assert final is not None
        np.testing.assert_array_equal(
            np.asarray(final.index_tables.pack_src), tables.pack_src)


# --- backends: generation tokens, remote semantics, tiering -----------------


def test_conditional_put_generation_tokens():
    """Backend CAS contract: a put conditioned on a stale token conflicts;
    ABSENT means create-only."""
    with tempfile.TemporaryDirectory() as d:
        be = LocalDirBackend(d)
        be.put_bytes("k", b"v1", if_generation=ABSENT)       # create-only ok
        with pytest.raises(GenerationConflict):
            be.put_bytes("k", b"v2", if_generation=ABSENT)   # already exists
        data, gen = be.get_with_generation("k")
        assert data == b"v1" and gen != ABSENT
        be.put_bytes("k", b"v2", if_generation=gen)          # fresh token ok
        with pytest.raises(GenerationConflict):
            be.put_bytes("k", b"v3", if_generation=gen)      # token now stale
        assert be.get_bytes("k") == b"v2"
        assert be.get_with_generation("missing") == (None, ABSENT)


def test_fsremote_is_bytes_only_roundtrip():
    """The remote backend round-trips through codec.loads — no local path,
    no memmap; tables come back as plain in-memory arrays."""
    sig, art, tables = _baked_artifact(np.full((4, 4), 3))
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(FsRemoteBackend(d))
        store.put_artifact(sig, art)
        assert store.path_for(sig) is None
        got = PlanStore(FsRemoteBackend(d)).get(sig)
        assert got is not None and got.payload_kind == "baked_tables"
        assert not isinstance(got.index_tables.pack_src, np.memmap)
        np.testing.assert_array_equal(
            np.asarray(got.index_tables.pack_src), tables.pack_src)


@pytest.mark.parametrize("defect", ["truncate", "garbage", "tamper"])
def test_fsremote_corruption_is_miss_through_bytes_path(defect):
    """The corruption-is-a-miss property holds for remote entries decoded
    via codec.loads exactly as it does for memmapped local files."""
    counts = np.full((4, 4), 7)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        writer = PlanStore(FsRemoteBackend(d))
        writer.put_artifact(sig, art)
        obj = os.path.join(d, writer.key_for(sig) + ".plan")
        if defect == "truncate":
            with open(obj, "r+b") as f:
                f.truncate(os.path.getsize(obj) // 2)
        elif defect == "garbage":
            with open(obj, "wb") as f:
                f.write(os.urandom(256))
        else:
            art.jax_version = "9.9.9"          # metadata no longer matches
            with open(obj, "wb") as f:
                codec.dump(art, f)
        store = PlanStore(FsRemoteBackend(d))
        assert store.get(sig) is None
        assert store.invalid == 1
        assert not os.path.exists(obj)          # bad entry removed remotely


def test_fsremote_failure_injection_degrades_to_miss():
    """A flaky remote never crashes INIT: reads count as misses (errors
    tracked), writes surface RemoteUnavailable (an OSError) for the
    best-effort layer above."""
    counts = np.full((4, 4), 5)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        down = PlanStore(FsRemoteBackend(d, fail_rate=1.0))
        assert down.get(sig) is None
        assert down.errors == 1 and down.misses == 1
        with pytest.raises(RemoteUnavailable):
            down.put_artifact(sig, art)
        assert isinstance(RemoteUnavailable("x"), OSError)


def test_tiered_promotion_memmaps_locally():
    """Remote hit populates the local cache (raw entry bytes), the promoted
    artifact memmaps off the local file, and the second get never touches
    the remote."""
    sig, art, tables = _baked_artifact(np.full((4, 4), 9))
    with tempfile.TemporaryDirectory() as remote_dir, \
            tempfile.TemporaryDirectory() as local_dir:
        PlanStore(FsRemoteBackend(remote_dir)).put_artifact(sig, art)
        remote_be = FsRemoteBackend(remote_dir)
        tiered = TieredPlanStore(PlanStore(local_dir),
                                 PlanStore(remote_be))
        got = tiered.get(sig)
        assert got is not None and tiered.promotions == 1
        assert isinstance(got.index_tables.pack_src, np.memmap)
        np.testing.assert_array_equal(
            np.asarray(got.index_tables.pack_src), tables.pack_src)
        ops_after_first = remote_be.ops
        again = tiered.get(sig)                  # local tier now owns it
        assert isinstance(again.index_tables.pack_src, np.memmap)
        assert remote_be.ops == ops_after_first  # no remote round trip
        assert tiered.local.hits == 1


def test_tiered_writeback_publish_and_remote_down():
    """Puts land in both tiers; with the remote down, gets fall back to the
    local cache and puts stay best-effort (remote_errors counts)."""
    sig, art, _ = _baked_artifact(np.full((4, 4), 6))
    with tempfile.TemporaryDirectory() as remote_dir, \
            tempfile.TemporaryDirectory() as local_dir:
        tiered = TieredPlanStore(PlanStore(local_dir),
                                 PlanStore(FsRemoteBackend(remote_dir)))
        tiered.put_artifact(sig, art)
        assert PlanStore(FsRemoteBackend(remote_dir)).get(sig) is not None
        assert PlanStore(local_dir).get(sig) is not None

        broken = TieredPlanStore(
            PlanStore(local_dir),
            PlanStore(FsRemoteBackend(remote_dir, fail_rate=1.0)))
        assert broken.get(sig) is not None       # local hit, remote untouched
        broken.put_artifact(sig, art)            # no raise
        assert broken.remote_errors == 1
        # empty local + dead remote = miss, never a crash
        with tempfile.TemporaryDirectory() as empty:
            dead = TieredPlanStore(
                PlanStore(empty),
                PlanStore(FsRemoteBackend(remote_dir, fail_rate=1.0)))
            assert dead.get(sig) is None and dead.remote_errors == 1


def test_tiered_eviction_under_reader():
    """Local-tier eviction unlinking a promoted entry does not disturb a
    reader already holding its memmapped tables (POSIX fd semantics)."""
    sig, art, tables = _baked_artifact(np.full((4, 4), 8))
    with tempfile.TemporaryDirectory() as remote_dir, \
            tempfile.TemporaryDirectory() as local_dir:
        PlanStore(FsRemoteBackend(remote_dir)).put_artifact(sig, art)
        tiered = TieredPlanStore(PlanStore(local_dir),
                                 PlanStore(FsRemoteBackend(remote_dir)))
        got = tiered.get(sig)
        assert isinstance(got.index_tables.pack_src, np.memmap)
        assert tiered.local.purge() == 1          # evicted under the reader
        np.testing.assert_array_equal(
            np.asarray(got.index_tables.pack_src), tables.pack_src)


def test_parse_store_url():
    with tempfile.TemporaryDirectory() as d:
        local = parse_store_url(os.path.join(d, "a"))
        assert isinstance(local, PlanStore)
        assert isinstance(local.store_backend, LocalDirBackend)
        filed = parse_store_url("file://" + os.path.join(d, "b"))
        assert isinstance(filed.store_backend, LocalDirBackend)
        rem = parse_store_url(
            f"fsremote://{d}/r?latency_ms=1.5&fail_rate=0.25&seed=7")
        assert isinstance(rem.store_backend, FsRemoteBackend)
        assert rem.store_backend.latency_ms == 1.5
        assert rem.store_backend.fail_rate == 0.25
        tiered = parse_store_url(
            f"tiered:local={d}/cache,remote=fsremote://{d}/shared")
        assert isinstance(tiered, TieredPlanStore)
        assert isinstance(tiered.local.store_backend, LocalDirBackend)
        assert isinstance(tiered.remote.store_backend, FsRemoteBackend)
        for bad in ("tiered:remote=x", "tiered:local=a", "fsremote://",
                    f"fsremote://{d}/r?bogus=1"):
            with pytest.raises(ValueError):
                parse_store_url(bad)


class _RacingBackend(LocalDirBackend):
    """Injects one competing put_auto between a merge's read and its
    conditional put — the exact interleave that used to drop the decision."""

    def __init__(self, root, store_factory, sig, choice):
        super().__init__(root)
        self._store_factory = store_factory
        self._sig = sig
        self._choice = choice
        self._raced = False

    def get_with_generation(self, key):
        out = super().get_with_generation(key)
        if not self._raced:
            self._raced = True
            self._store_factory().put_auto(self._sig, self._choice)
        return out


def test_attach_breakeven_merges_with_concurrent_auto_publish():
    """Deterministic interleave: another process publishes an auto decision
    after attach_breakeven reads the entry.  The conditional put detects
    the generation change, re-reads, and merges — the decision survives
    (last-writer-wins silently dropped it)."""
    counts = np.full((4, 4), 12)
    sig = _sig(counts, variant="auto")
    choice = {"variant": "lock", "times": {"lock": 5e-5}}
    with tempfile.TemporaryDirectory() as d:
        be = _RacingBackend(d, lambda: PlanStore(d), sig, choice)
        store = PlanStore(be)
        store.attach_breakeven(sig, {"t_init": 1e-3, "n_breakeven": 21})
        final = PlanStore(d).get(sig)
        assert final.auto_choice == choice              # not dropped
        assert final.breakeven["n_breakeven"] == 21     # and merged


def test_tiered_merge_refreshes_local_from_remote():
    """A tiered merge runs against the authoritative remote and mirrors the
    merged entry into the local cache — an independent local merge used to
    create a meta-only local entry that shadowed the remote's tables on
    every later get (defeating the fleet warm start)."""
    sig, art, _ = _baked_artifact(np.full((4, 4), 4))
    with tempfile.TemporaryDirectory() as remote_dir, \
            tempfile.TemporaryDirectory() as local_dir:
        PlanStore(FsRemoteBackend(remote_dir)).put_artifact(sig, art)
        tiered = TieredPlanStore(PlanStore(local_dir),
                                 PlanStore(FsRemoteBackend(remote_dir)))
        tiered.attach_breakeven(sig, {"t_init": 2e-3})
        local_art = PlanStore(local_dir).get(sig)
        assert local_art.payload_kind == "baked_tables"   # not meta-only
        assert local_art.breakeven["t_init"] == 2e-3
        got = tiered.get(sig)
        assert got.payload_kind == "baked_tables" and got.breakeven


def test_tiered_with_bytes_only_local_tier_still_serves():
    """Nothing stops a bytes-only backend in the local slot; promotion then
    simply returns the decoded remote artifact instead of crashing on the
    absent local path."""
    sig, art, tables = _baked_artifact(np.full((4, 4), 3))
    with tempfile.TemporaryDirectory() as remote_dir, \
            tempfile.TemporaryDirectory() as local_dir:
        PlanStore(FsRemoteBackend(remote_dir)).put_artifact(sig, art)
        tiered = TieredPlanStore(PlanStore(FsRemoteBackend(local_dir)),
                                 PlanStore(FsRemoteBackend(remote_dir)))
        got = tiered.get(sig)
        assert got is not None
        np.testing.assert_array_equal(
            np.asarray(got.index_tables.pack_src), tables.pack_src)


def test_put_plan_preserves_preattached_breakeven():
    """attach_breakeven can create a meta-only entry before any tables
    exist (breakeven_model measures patterns it never warm-loads); the
    later cold INIT's table publish must merge into it, not replace it."""
    counts = np.full((4, 4), 10)
    sig, _, tables = _baked_artifact(counts)

    class FakePlan:
        index_tables = tables
        hier_schedule = None

    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        store.attach_breakeven(sig, {"t_init": 1e-3, "n_breakeven": 7})
        assert store.put_plan(sig, FakePlan) is not None
        got = PlanStore(d).get(sig)
        assert got.payload_kind == "baked_tables"
        assert got.breakeven["n_breakeven"] == 7     # survived the publish


def test_remote_store_is_never_lru_trimmed_by_clients():
    """A client's local max_entries must not evict entries from a shared
    remote store (another replica may still need them); remote lifecycle
    belongs to the object store's retention policy."""
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(FsRemoteBackend(d), max_entries=2)
        for i in range(5):
            sig, art, _ = _baked_artifact(np.full((4, 4), i + 1))
            store.put_artifact(sig, art)
        assert len(store.entries()) == 5 and store.evictions == 0
        # local dirs keep today's LRU bound
        local = PlanStore(os.path.join(d, "local"), max_entries=2)
        for i in range(5):
            sig, art, _ = _baked_artifact(np.full((4, 4), i + 1))
            local.put_artifact(sig, art)
            os.utime(local.path_for(sig), (i, i))
        assert len(local.entries()) <= 2


def _hammer_merge(args):
    """Worker for the merge-concurrency hammer: interleave put_auto and
    attach_breakeven on one key; every merge must converge."""
    root, seed, rounds = args
    rng = np.random.default_rng(seed)
    counts = np.full((4, 4), 13)           # same signature for every worker
    sig = _sig(counts, variant="auto")
    store = PlanStore(root)
    for i in range(rounds):
        if rng.random() < 0.5:
            store.put_auto(sig, {"variant": "lock",
                                 "times": {"lock": float(seed)}})
        else:
            store.attach_breakeven(sig, {"t_init": float(i)}, retries=50)
    return store.stats


def test_concurrent_merges_never_drop_fields():
    """Many processes interleaving put_auto and attach_breakeven on one
    entry: the final entry holds BOTH an auto decision and a break-even fit
    — the read-modify-write merges instead of overwriting."""
    import multiprocessing as mp

    with tempfile.TemporaryDirectory() as d:
        # Seed both fields so the assertion is meaningful regardless of
        # which worker's op lands last.
        counts = np.full((4, 4), 13)
        sig = _sig(counts, variant="auto")
        seed_store = PlanStore(d)
        seed_store.put_auto(sig, {"variant": "fence", "times": {}})
        seed_store.attach_breakeven(sig, {"t_init": 0.0})
        with mp.get_context("spawn").Pool(4) as pool:
            pool.map(_hammer_merge, [(d, seed, 12) for seed in range(4)])
        final = PlanStore(d).get(sig)
        assert final is not None
        assert final.auto_choice is not None and "variant" in final.auto_choice
        assert final.breakeven is not None and "t_init" in final.breakeven


def test_plan_cache_warm_integration_single_device():
    """Two-tier integration without multi-device: a 1-rank plan cold-builds
    and publishes; a fresh cache + fresh store handle warm-loads the same
    tensors with zero bakes (the full-mesh version is the dist case)."""
    import jax.numpy as jnp

    from repro.core import INIT_STATS, AlltoallvSpec, PlanCache
    from repro.launch.mesh import make_host_mesh

    counts = np.array([[24]])
    mesh = make_host_mesh(1)
    spec = AlltoallvSpec(send_counts=counts, feature_shape=(4,),
                         dtype=jnp.float32, axis=("x",))
    with tempfile.TemporaryDirectory() as d:
        INIT_STATS.reset()
        plan = PlanCache().get(spec, mesh, store=PlanStore(d))
        assert not plan.warm_loaded and INIT_STATS.table_bakes == 1
        INIT_STATS.reset()
        plan2 = PlanCache().get(spec, mesh, store=PlanStore(d))
        assert plan2.warm_loaded and INIT_STATS.table_bakes == 0
        assert INIT_STATS.warm_inits == 1
        for name in ("pack_src", "pack_valid", "unpack_src", "unpack_valid"):
            np.testing.assert_array_equal(
                np.asarray(getattr(plan2.index_tables, name)),
                np.asarray(getattr(plan.index_tables, name)))
