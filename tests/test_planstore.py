"""Plan-store unit tests: codec round-trips, corruption handling, version
invalidation, LRU eviction.

Everything here is host-side (numpy + files); the multi-device warm-start
identity checks live in test_distributed.py (planstore_warm_start).
"""

import os
import tempfile

import numpy as np
import pytest

from _hypothesis_compat import given, strategies as st
from repro.core import metadata as md
from repro.planstore import (ArtifactError, PlanArtifact, PlanStore,
                             SCHEMA_VERSION, codec, signature_meta, store_key)

counts_matrices = st.integers(2, 10).flatmap(
    lambda p: st.lists(
        st.lists(st.integers(0, 50), min_size=p, max_size=p),
        min_size=p, max_size=p).map(np.array))

hier_counts = st.integers(1, 4).flatmap(
    lambda p_inner: st.lists(
        st.lists(st.integers(0, 30), min_size=2 * p_inner, max_size=2 * p_inner),
        min_size=2 * p_inner, max_size=2 * p_inner).map(
            lambda rows: (np.array(rows), p_inner)))


def _sig(counts, variant="fence", axis=("x",), axis_sizes=None, **kw):
    p = counts.shape[0]
    return md.PatternSignature.build(
        counts, (4,), "float32", variant, axis, 16,
        axis_sizes=axis_sizes if axis_sizes is not None else (p,), **kw)


def _baked_artifact(counts):
    cap = md.global_capacity(counts)
    recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
    tables = md.baked_index_tables(counts, cap, recv_rows)
    sig = _sig(counts)
    return sig, PlanArtifact(signature=signature_meta(sig),
                             index_tables=tables), tables


@given(counts_matrices)
def test_baked_tables_roundtrip(counts):
    """signature -> save -> load under a fresh store handle -> identical
    plan tensors, bit for bit."""
    sig, art, tables = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_artifact(sig, art)
        got = PlanStore(d).get(sig)
        assert got is not None and got.payload_kind == "baked_tables"
        for name in ("pack_src", "pack_valid", "unpack_src", "unpack_valid"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got.index_tables, name)),
                getattr(tables, name))


@given(hier_counts)
def test_hier_schedule_roundtrip(counts_and_inner):
    counts, p_inner = counts_and_inner
    p = counts.shape[0]
    recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
    sched = md.hier_two_stage_schedule(counts, 2, p_inner, recv_rows)
    sig = _sig(counts, variant="fence_hierarchy", axis=("o", "i"),
               axis_sizes=(2, p_inner))
    art = PlanArtifact(signature=signature_meta(sig), hier_schedule=sched)
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_artifact(sig, art)
        got = PlanStore(d).get(sig).hier_schedule
        assert (got.p_outer, got.p_inner, got.n_macro, got.remote_needed,
                got.s1_cap, got.s2_caps, got.s2_offs, got.total_s2,
                got.s3_cap, got.round_perms, got.cross_group_puts) == (
            sched.p_outer, sched.p_inner, sched.n_macro, sched.remote_needed,
            sched.s1_cap, sched.s2_caps, sched.s2_offs, sched.total_s2,
            sched.s3_cap, sched.round_perms, sched.cross_group_puts)
        for a, b in zip(got.tables, sched.tables):
            np.testing.assert_array_equal(np.asarray(a), b)
        assert p == got.unpack_src.shape[0]


def test_auto_choice_roundtrip():
    counts = np.full((4, 4), 3)
    sig = _sig(counts, variant="auto")
    choice = {"variant": "lock", "times": {"fence": 1e-4, "lock": 5e-5}}
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_auto(sig, choice)
        assert PlanStore(d).get_auto(sig) == choice


def test_truncated_entry_is_miss_not_crash():
    counts = np.full((4, 4), 7)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        path = PlanStore(d).put_artifact(sig, art)
        with open(path, "r+b") as f:
            f.truncate(os.path.getsize(path) // 2)
        store = PlanStore(d)
        assert store.get(sig) is None
        assert store.invalid == 1
        assert not os.path.exists(path)        # bad entry removed
        # and the slot is reusable: a fresh put round-trips again
        store.put_artifact(sig, art)
        assert store.get(sig) is not None


def test_garbage_entry_is_miss_not_crash():
    counts = np.full((4, 4), 5)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        with open(store.path_for(sig), "wb") as f:
            f.write(os.urandom(512))
        assert store.get(sig) is None and store.invalid == 1


def test_jax_version_mismatch_falls_back_cold():
    """An entry written under another jax version is keyed differently, so
    the live store simply misses (cold INIT) — stale tables never load."""
    counts = np.full((4, 4), 9)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        other = PlanStore(d, jax_ver="9.9.9")
        other.put_artifact(sig, art)
        live = PlanStore(d)
        assert live.path_for(sig) != other.path_for(sig)
        assert live.get(sig) is None and live.misses == 1
        # other-version store still finds its own entry
        assert PlanStore(d, jax_ver="9.9.9").get(sig) is not None


@pytest.mark.parametrize("field", ["jax", "repro", "schema"])
def test_tampered_entry_fails_meta_validation(field):
    """Key collisions cannot happen through the API, but a hand-copied file
    at the right path must still be rejected by metadata validation."""
    counts = np.full((4, 4), 4)
    sig, art, _ = _baked_artifact(counts)
    if field == "jax":
        art.jax_version = "9.9.9"
    elif field == "repro":
        art.repro_version = "0.0.0-other"
    else:
        art.schema_version = SCHEMA_VERSION + 1
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        with open(store.path_for(sig), "wb") as f:   # bypass put_artifact
            codec.dump(art, f)
        assert store.get(sig) is None and store.invalid == 1


def test_backend_mismatch_falls_back_cold():
    """Auto decisions measured on one backend must not be served to another
    (CPU timings would pin the wrong variant for a TPU process)."""
    counts = np.full((4, 4), 9)
    sig = _sig(counts, variant="auto")
    choice = {"variant": "ragged", "times": {"ragged": 1e-5}}
    with tempfile.TemporaryDirectory() as d:
        tpu_store = PlanStore(d, backend="tpu")
        tpu_store.put_auto(sig, choice)
        live = PlanStore(d)                     # cpu on this host
        assert live.path_for(sig) != tpu_store.path_for(sig)
        assert live.get_auto(sig) is None
        # and each backend's store keeps its own decision intact
        assert PlanStore(d, backend="tpu").get_auto(sig) == choice


def test_axis_sizes_mismatch_is_a_different_key():
    counts = np.full((8, 8), 3)
    s24 = _sig(counts, variant="fence_hierarchy", axis=("o", "i"),
               axis_sizes=(2, 4))
    s42 = _sig(counts, variant="fence_hierarchy", axis=("o", "i"),
               axis_sizes=(4, 2))
    assert store_key(s24) != store_key(s42)
    with tempfile.TemporaryDirectory() as d:
        recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
        sched = md.hier_two_stage_schedule(counts, 2, 4, recv_rows)
        PlanStore(d).put_artifact(
            s24, PlanArtifact(signature=signature_meta(s24),
                              hier_schedule=sched))
        store = PlanStore(d)
        assert store.get(s42) is None          # (4,2) never sees (2,4) tables
        assert store.get(s24) is not None


def test_signature_tamper_rejected():
    """Same file renamed under another signature's key: the signature echo
    in the metadata does not match and validation treats it as a miss."""
    a = np.full((4, 4), 3)
    b = np.full((4, 4), 8)
    sig_a, art_a, _ = _baked_artifact(a)
    sig_b = _sig(b)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        src = store.put_artifact(sig_a, art_a)
        os.replace(src, store.path_for(sig_b))
        assert store.get(sig_b) is None and store.invalid == 1


def test_lru_eviction_bounds_entries():
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d, max_entries=3)
        sigs = []
        for i in range(5):
            counts = np.full((4, 4), i + 1)
            sig, art, _ = _baked_artifact(counts)
            sigs.append(sig)
            store.put_artifact(sig, art)
            # distinct mtimes even on coarse-clock filesystems
            os.utime(store.path_for(sig), (i, i))
        assert len(store.entries()) <= 3
        assert store.get(sigs[0]) is None      # oldest evicted
        assert store.get(sigs[-1]) is not None  # newest kept


def test_stale_tmp_files_swept_on_put():
    """Staging files orphaned by killed writers get cleaned up by later
    puts; a fresh (in-flight) tmp file is left alone."""
    counts = np.full((4, 4), 6)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        stale = os.path.join(d, "tmp-999-deadbeef.plan")
        fresh = os.path.join(d, "tmp-999-cafef00d.plan")
        for p in (stale, fresh):
            with open(p, "wb") as f:
                f.write(b"partial write")
        os.utime(stale, (0, 0))                 # ancient
        store.put_artifact(sig, art)            # triggers the sweep
        assert not os.path.exists(stale)
        assert os.path.exists(fresh)


def test_attach_breakeven_merges_into_entry():
    counts = np.full((4, 4), 6)
    sig, art, _ = _baked_artifact(counts)
    with tempfile.TemporaryDirectory() as d:
        store = PlanStore(d)
        store.put_artifact(sig, art)
        store.attach_breakeven(sig, {"t_init": 1e-3, "t_persist": 2e-5,
                                     "t_mpi": 5e-5, "n_breakeven": 34})
        got = store.get(sig)
        assert got.breakeven["n_breakeven"] == 34
        assert got.index_tables is not None    # tables survived the merge


def test_dumps_loads_bytes_roundtrip():
    counts = np.full((4, 4), 2)
    _, art, tables = _baked_artifact(counts)
    got = codec.loads(codec.dumps(art))
    np.testing.assert_array_equal(got.index_tables.pack_src, tables.pack_src)


def test_empty_and_meta_only_artifacts():
    counts = np.zeros((4, 4), np.int64)
    sig = _sig(counts, variant="auto")
    art = PlanArtifact(signature=signature_meta(sig),
                       auto_choice={"variant": "fence", "times": {}})
    assert art.payload_kind == "meta_only"
    with tempfile.TemporaryDirectory() as d:
        PlanStore(d).put_artifact(sig, art)
        got = PlanStore(d).get(sig)
        assert got.index_tables is None and got.hier_schedule is None


def _hammer_store(args):
    """Worker for the concurrency test: alternate puts and gets of the same
    entry; return how many valid loads and how many misses were observed."""
    root, seed, rounds = args
    rng = np.random.default_rng(seed)
    counts = np.full((4, 4), 11)           # same signature for every worker
    sig, art, tables = _baked_artifact(counts)
    store = PlanStore(root)
    loads = misses = 0
    for _ in range(rounds):
        if rng.random() < 0.5:
            store.put_artifact(sig, art)
        got = store.get(sig)
        if got is None:
            misses += 1
        else:
            loads += 1
            np.testing.assert_array_equal(
                np.asarray(got.index_tables.pack_src), tables.pack_src)
    return loads, misses


def test_concurrent_writers_never_corrupt():
    """Many processes hammering one key: every successful read decodes to
    the exact tables (torn writes would fail decode; decode failures would
    delete the entry and show up as misses after the first put)."""
    import multiprocessing as mp

    with tempfile.TemporaryDirectory() as d:
        with mp.get_context("spawn").Pool(4) as pool:
            results = pool.map(_hammer_store,
                               [(d, seed, 20) for seed in range(4)])
        total_loads = sum(r[0] for r in results)
        assert total_loads > 0
        # the entry left behind is itself valid
        counts = np.full((4, 4), 11)
        sig, _, tables = _baked_artifact(counts)
        final = PlanStore(d).get(sig)
        assert final is not None
        np.testing.assert_array_equal(
            np.asarray(final.index_tables.pack_src), tables.pack_src)


def test_plan_cache_warm_integration_single_device():
    """Two-tier integration without multi-device: a 1-rank plan cold-builds
    and publishes; a fresh cache + fresh store handle warm-loads the same
    tensors with zero bakes (the full-mesh version is the dist case)."""
    import jax.numpy as jnp

    from repro.core import INIT_STATS, AlltoallvSpec, PlanCache
    from repro.launch.mesh import make_host_mesh

    counts = np.array([[24]])
    mesh = make_host_mesh(1)
    spec = AlltoallvSpec(send_counts=counts, feature_shape=(4,),
                         dtype=jnp.float32, axis=("x",))
    with tempfile.TemporaryDirectory() as d:
        INIT_STATS.reset()
        plan = PlanCache().get(spec, mesh, store=PlanStore(d))
        assert not plan.warm_loaded and INIT_STATS.table_bakes == 1
        INIT_STATS.reset()
        plan2 = PlanCache().get(spec, mesh, store=PlanStore(d))
        assert plan2.warm_loaded and INIT_STATS.table_bakes == 0
        assert INIT_STATS.warm_inits == 1
        for name in ("pack_src", "pack_valid", "unpack_src", "unpack_valid"):
            np.testing.assert_array_equal(
                np.asarray(getattr(plan2.index_tables, name)),
                np.asarray(getattr(plan.index_tables, name)))
