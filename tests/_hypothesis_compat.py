"""``hypothesis`` facade for the property tests.

When hypothesis is installed (requirements-dev.txt) this module simply
re-exports it.  In a bare environment it degrades to a small deterministic
random-sampling engine implementing exactly the strategy surface the suite
uses (``integers``, ``floats``, ``lists``, ``data``, ``map``/``flatmap``),
so the property tests still *run* — with fixed seeds and fewer guarantees —
instead of erroring at collection.
"""

from __future__ import annotations

import functools
import inspect
import zlib

try:
    from hypothesis import HealthCheck, given, settings, strategies  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._sample(rng)))

        def flatmap(self, f):
            return _Strategy(lambda rng: f(self._sample(rng)).example(rng))

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.example(self._rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class _st:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(sample)

        @staticmethod
        def data():
            return _DataStrategy()

    st = strategies = _st

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = _np.random.default_rng(seed)
                for _ in range(n):
                    fn(*[s.example(rng) for s in strategies])

            wrapper.__signature__ = inspect.Signature()
            wrapper._max_examples = _DEFAULT_MAX_EXAMPLES
            return wrapper

        return deco

    class settings:  # noqa: N801 — mirrors hypothesis.settings
        def __init__(self, max_examples=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            if self.max_examples is not None:
                fn._max_examples = self.max_examples
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass

    class HealthCheck:  # noqa: N801
        too_slow = "too_slow"
        data_too_large = "data_too_large"
