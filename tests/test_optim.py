"""Optimizer / schedule / grad-utility unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import grad as grad_util
from repro.train import optimizer as opt_mod
from repro.train import schedule as sched_mod


def test_adamw_matches_reference():
    """Two steps of our AdamW == a straightforward numpy implementation."""
    cfg = opt_mod.AdamWConfig(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                              master_weights=False)
    rng = np.random.default_rng(0)
    p0 = rng.standard_normal((4, 3)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = opt_mod.init_opt_state(params, cfg)
    lr = 1e-2

    m = np.zeros_like(p0)
    v = np.zeros_like(p0)
    p_ref = p0.copy()
    for t in range(1, 3):
        g = rng.standard_normal(p0.shape).astype(np.float32)
        params, state = opt_mod.adamw_update({"w": jnp.asarray(g)}, state,
                                             params, lr, cfg)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** t)
        vh = v / (1 - cfg.b2 ** t)
        p_ref = p_ref - lr * (mh / (np.sqrt(vh) + cfg.eps)
                              + cfg.weight_decay * p_ref)
        np.testing.assert_allclose(np.asarray(params["w"]), p_ref, rtol=1e-5)


def test_adamw_master_weights_bf16():
    """bf16 params keep full-precision masters; updates accumulate there."""
    cfg = opt_mod.AdamWConfig(weight_decay=0.0, master_weights=True)
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = opt_mod.init_opt_state(params, cfg)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((8,), 1e-4, jnp.float32)}
    for _ in range(10):
        params, state = opt_mod.adamw_update(g, state, params, 1e-5, cfg)
    # master moved even though each bf16 step may round to nothing
    assert float(jnp.max(jnp.abs(state["master"]["w"] - 1.0))) > 0
    assert params["w"].dtype == jnp.bfloat16


def test_schedules():
    cfg = sched_mod.ScheduleConfig(kind="wsd", peak_lr=1.0, min_lr_ratio=0.1,
                                   warmup_steps=10, total_steps=100,
                                   decay_steps=20)
    # warmup
    assert float(sched_mod.lr_at(cfg, 0)) == 0.0
    assert abs(float(sched_mod.lr_at(cfg, 5)) - 0.5) < 1e-6
    # stable plateau
    assert abs(float(sched_mod.lr_at(cfg, 50)) - 1.0) < 1e-6
    assert abs(float(sched_mod.lr_at(cfg, 79)) - 1.0) < 1e-6
    # decay tail
    assert abs(float(sched_mod.lr_at(cfg, 100)) - 0.1) < 1e-6
    mid = float(sched_mod.lr_at(cfg, 90))
    assert 0.1 < mid < 1.0

    cos = sched_mod.ScheduleConfig(kind="cosine", peak_lr=1.0, warmup_steps=0,
                                   total_steps=100, min_lr_ratio=0.0)
    assert abs(float(sched_mod.lr_at(cos, 0)) - 1.0) < 1e-6
    assert abs(float(sched_mod.lr_at(cos, 100))) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = grad_util.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - np.sqrt(250)) < 1e-4
    new_norm = grad_util.global_norm(clipped)
    assert abs(float(new_norm) - 1.0) < 1e-5
    # below threshold -> untouched
    clipped2, _ = grad_util.clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 3.0, rtol=1e-6)


def test_accumulate_grads_matches_full_batch():
    """n_micro=4 accumulation == single-shot full-batch grads."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean(jnp.square(pred - batch["y"]))
        return l, {"l": l}

    batch = {"x": x, "y": y}
    l1, m1, g1 = grad_util.accumulate_grads(loss_fn, {"w": w}, batch, 1)
    l4, m4, g4 = grad_util.accumulate_grads(loss_fn, {"w": w}, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               rtol=1e-5)


def test_zero1_pspec_divisibility():
    import jax as _jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh
    from repro.parallel.sharding import axis_rules, DEFAULT_RULES

    import dataclasses

    @dataclasses.dataclass
    class FakeMesh:
        shape: dict
        @property
        def axis_names(self):
            return tuple(self.shape)

    mesh = FakeMesh({"data": 4, "model": 2})
    with axis_rules(DEFAULT_RULES, mesh):
        # indivisible dims are never sharded
        spec = opt_mod.zero1_pspec(("embed", "ff"), (7, 13), mesh)
        assert spec == P()
        # divisible dim0 gets the data axis on top of model on dim1
        spec = opt_mod.zero1_pspec(("embed", "ff"), (8, 12), mesh)
        assert spec == P("data", "model")
