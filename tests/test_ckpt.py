"""Checkpoint manager: roundtrip, atomicity, retention, async."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "blocks": [{"a": jnp.ones((2,))}, {"a": jnp.zeros((2,))}]},
            "opt": {"m": {"w": jnp.full((3, 4), 0.5)},
                    "count": jnp.int32(7)}}


def test_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        trees = _tree()
        mgr.save(3, trees, extras={"data": {"step": 3}})
        step, loaded, extras = mgr.load()
        assert step == 3 and extras["data"]["step"] == 3
        np.testing.assert_array_equal(loaded["params"]["w"],
                                      np.asarray(trees["params"]["w"]))
        np.testing.assert_array_equal(loaded["params"]["blocks"][1]["a"],
                                      np.zeros((2,)))
        assert int(loaded["opt"]["count"]) == 7


def test_retention_and_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"params": {"w": jnp.full((2,), float(s))}})
        assert mgr.all_steps() == [3, 4]
        assert mgr.latest_step() == 4
        _, loaded, _ = mgr.load(step=3)
        np.testing.assert_array_equal(loaded["params"]["w"], [3.0, 3.0])


def test_incomplete_checkpoint_ignored():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"params": {"w": jnp.ones((2,))}})
        # fake a torn write: directory without the commit marker
        os.makedirs(os.path.join(d, "step_000000009"))
        assert mgr.latest_step() == 1


def test_async_save():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=True)
        mgr.save(5, {"params": {"w": jnp.ones((128, 128))}})
        mgr.wait()
        assert mgr.latest_step() == 5
