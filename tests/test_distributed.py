"""Multi-device correctness: every case runs in a subprocess with fake host
devices (the device count must precede jax init; see conftest.run_case)."""

import pytest


def test_alltoallv_variants(dist):
    dist("alltoallv_variants", devices=8)


def test_alltoallv_small_world(dist):
    dist("alltoallv_variants", devices=2)


def test_alltoallv_dtypes_and_features(dist):
    dist("alltoallv_dtypes_and_features", devices=4)


def test_plan_and_window_reuse(dist):
    dist("plan_and_window_reuse", devices=4)


def test_ragged_backend_lowers(dist):
    dist("ragged_backend_lowers", devices=8)


def test_rma_kernels(dist):
    dist("rma_kernels", devices=4)


def test_pallas_pack_in_plan(dist):
    dist("pallas_pack_in_plan", devices=4)


def test_sparse_lock_elision(dist):
    dist("sparse_lock_elision", devices=8)


def test_hierarchy_local_elision(dist):
    dist("hierarchy_local_elision", devices=8)


def test_hier_combined_parity(dist):
    dist("hier_combined_parity", devices=8)


def test_hier_combined_parity_small_world(dist):
    dist("hier_combined_parity", devices=4)


def test_auto_variant_dispatch(dist):
    dist("auto_variant_dispatch", devices=8)


def test_auto_ragged_candidate(dist):
    dist("auto_ragged_candidate", devices=8)


def test_planstore_warm_start(dist):
    dist("planstore_warm_start", devices=8)


def test_planstore_fleet_prewarm(dist):
    dist("planstore_fleet_prewarm", devices=8)


def test_gspmd_gather_miscompile_guard(dist):
    dist("gspmd_gather_miscompile_guard", devices=8)


def test_moe_hier_dispatch(dist):
    dist("moe_hier_dispatch", devices=8)


def test_ulysses_hier_attention(dist):
    dist("ulysses_hier_attention", devices=4)


def test_fused_pack_fence(dist):
    dist("fused_pack_fence", devices=4)


def test_pipelined_epochs(dist):
    dist("pipelined_epochs", devices=4)


def test_moe_dispatch_distributed(dist):
    dist("moe_dispatch_distributed", devices=8)


def test_embedded_plan_parity(dist):
    dist("embedded_plan_parity", devices=4)


def test_moe_plan_backed_parity(dist):
    dist("moe_plan_backed_parity", devices=8)


def test_moe_overlap_invariance(dist):
    dist("moe_overlap_invariance", devices=8)


def test_moe_planstore_warm_start(dist):
    dist("moe_planstore_warm_start", devices=8)


def test_moe_codec_dispatch_parity(dist):
    dist("moe_codec_dispatch_parity", devices=8)


def test_codec_planstore_warm_start(dist):
    dist("codec_planstore_warm_start", devices=8)


def test_compression_distributed(dist):
    dist("compression_distributed", devices=4)


def test_elastic_reshard(dist):
    dist("elastic_reshard", devices=4)


def test_ulysses_attention(dist):
    dist("ulysses_attention_matches_local", devices=4)


def test_hierarchical_psum(dist):
    dist("hierarchical_psum", devices=8)


def test_allgatherv_plan_parity(dist):
    dist("allgatherv_plan_parity", devices=8)


def test_reduce_scatter_grad_parity(dist):
    dist("reduce_scatter_grad_parity", devices=8)


def test_gatherv_planstore_warm_start(dist):
    dist("gatherv_planstore_warm_start", devices=8)


def test_moe_ragged_tail_combine(dist):
    dist("moe_ragged_tail_combine", devices=8)


def test_replan_hot_swap(dist):
    dist("replan_hot_swap", devices=8, timeout=1800)


def test_leader_rebake_recovery(dist):
    dist("leader_rebake_recovery", devices=8, timeout=1800)


def test_elastic_resume(dist):
    dist("elastic_resume", devices=8)


def test_chaos_recovery(dist):
    dist("chaos_recovery", devices=8)


def test_production_mesh_mini(dist):
    dist("production_mesh_mini", devices=8, timeout=1800)


def test_obs_trace_contract(dist):
    dist("obs_trace_contract", devices=8)
