"""MoE routing property tests (hypothesis) + single-device dispatch checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod


@settings(max_examples=20)
@given(st.integers(8, 64), st.integers(2, 16), st.integers(1, 4),
       st.integers(0, 10_000))
def test_route_invariants(t, e, k, seed):
    k = min(k, e)
    cap = max(4 * t * k // e, 2)
    rng = np.random.default_rng(seed)
    chunk = jnp.asarray(rng.standard_normal((t, 16)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((16, e)), jnp.float32)
    valid = jnp.asarray(rng.integers(0, 2, t).astype(bool))

    slot, keep, w, counts, (lb, z) = moe_mod._route(chunk, router, valid,
                                                    k, e, cap)
    slot, keep, w = map(np.asarray, (slot, keep, w))
    counts = np.asarray(counts)

    # kept slots are unique and within bounds
    kept = slot[keep]
    assert len(set(kept.tolist())) == len(kept)
    assert (kept < e * cap).all()
    # capacity respected per expert
    per_expert = np.bincount(kept // cap, minlength=e)
    assert (per_expert <= cap).all()
    # dropped/invalid entries point at the overflow slot
    assert (slot[~keep] == e * cap).all()
    # weights: normalized over kept+dropped slots per valid token, zero for invalid
    wt = w.reshape(t, k)
    v = np.asarray(valid)
    np.testing.assert_allclose(wt[v].sum(-1), 1.0, rtol=1e-5)
    assert (np.abs(wt[~v]) < 1e-9).all()
    # counts: one entry per (valid token, slot)
    assert counts.sum() == v.sum() * k
    # aux losses finite; lb ~ 1 when balanced, strictly positive always
    # (E*sum(f*p) >= 1 only when f == p exactly — top-1 f vs softmax p can
    # dip slightly below 1 on small token counts, found by hypothesis)
    assert np.isfinite(float(lb)) and np.isfinite(float(z))
    if v.sum() > 0:
        assert float(lb) > 0.5


def test_dispatch_impls_agree_single_device():
    base = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
    rng = np.random.default_rng(0)
    from repro.parallel.sharding import ParamFactory

    f = ParamFactory(jax.random.key(0), jnp.float32)
    moe_mod.init_moe(f.scope("moe"), 64, base)
    params = f.params["moe"]
    x = jnp.asarray(rng.standard_normal((2, 32, 64)), jnp.float32)

    outs = {}
    for dispatch in ("gspmd", "persistent_a2a", "nonpersistent_a2a"):
        mcfg = dataclasses.replace(base, dispatch=dispatch)
        plan = moe_mod.MoEDispatchPlan.build(mcfg, 64, None)
        y, aux = moe_mod.apply_moe(params, x, mcfg, plan)
        outs[dispatch] = np.asarray(y)
        assert np.isfinite(outs[dispatch]).all()
    np.testing.assert_allclose(outs["gspmd"], outs["persistent_a2a"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs["persistent_a2a"],
                               outs["nonpersistent_a2a"], rtol=1e-4, atol=1e-5)


def test_capacity_drops_are_weighted_zero():
    """With capacity factor << 1 most tokens drop; output must stay finite
    and dropped tokens contribute zero (not garbage)."""
    base = MoEConfig(n_experts=4, top_k=1, d_expert=16, capacity_factor=0.1)
    from repro.parallel.sharding import ParamFactory

    f = ParamFactory(jax.random.key(1), jnp.float32)
    moe_mod.init_moe(f.scope("moe"), 32, base)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 64, 32)),
                    jnp.float32)
    plan = moe_mod.MoEDispatchPlan.build(base, 64, None)
    y, aux = moe_mod.apply_moe(f.params["moe"], x, base, plan)
    assert bool(jnp.all(jnp.isfinite(y)))
    # most rows zero (dropped)
    zero_rows = int(jnp.sum(jnp.all(jnp.abs(y[0]) < 1e-9, axis=-1)))
    assert zero_rows >= 16  # capacity 8/expert x 4 experts keeps at most 32 of 64
