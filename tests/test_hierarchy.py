"""Leader-combined two-stage hierarchy: host-side schedule correctness.

The three-hop exchange (intra-group gather -> inter-group leader slabs ->
intra-group scatter) is fully described by the INIT-baked index tables in
``metadata.HierSchedule``.  These tests execute the schedule in pure numpy —
each collective replaced by its literal data movement — and require the
round trip to reproduce the global alltoallv oracle bit-for-bit, for dense,
banded, skewed, all-local, and randomized patterns across group shapes.
The multi-device (jax collective) halves live in test_distributed.py.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, strategies as st
from repro.core import metadata as md, reference


def _gather(src_tbl, valid_tbl, source):
    """Masked row gather: numpy twin of variants.pack_rows."""
    out = source[np.clip(src_tbl, 0, len(source) - 1)]
    mask = valid_tbl.reshape(valid_tbl.shape + (1,) * (out.ndim - 1))
    return np.where(mask, out, 0)


def simulate_two_stage(counts, p_outer, p_inner, bufs, recv_rows,
                       leader_perm=None):
    """Run the schedule with every collective spelled out in numpy.

    bufs: [P, send_rows, F...] per-rank ragged send buffers.
    Returns [P, recv_rows, F...].
    """
    hs = md.hier_two_stage_schedule(counts, p_outer, p_inner, recv_rows,
                                    leader_perm=leader_perm)
    p = p_outer * p_inner
    feat = bufs.shape[2:]

    # stage 1: pack + inner-axis all_to_all (bucket sq of my recv = bucket
    # q of local rank sq's send)
    s1w = hs.s1_src.shape[1]
    s1_recv = np.zeros((p, s1w) + feat, bufs.dtype)
    if hs.remote_needed:
        s1_send = np.stack(
            [_gather(hs.s1_src[g], hs.s1_valid[g], bufs[g]) for g in range(p)])
        for o in range(p_outer):
            for q in range(p_inner):
                for sq in range(p_inner):
                    s1_recv[o * p_inner + q, sq * hs.s1_cap:(sq + 1) * hs.s1_cap] = \
                        s1_send[o * p_inner + sq, q * hs.s1_cap:(q + 1) * hs.s1_cap]

    # stage 2: slab build + per-macro-round leader permutation
    s2_recv = np.zeros((p, hs.total_s2) + feat, bufs.dtype)
    if hs.remote_needed:
        s2_send = np.stack(
            [_gather(hs.s2_src[g], hs.s2_valid[g], s1_recv[g]) for g in range(p)])
        for m, perm in enumerate(hs.round_perms):
            off, cap = hs.s2_offs[m], hs.s2_caps[m]
            for src, dst in perm:
                s2_recv[dst, off:off + cap] = s2_send[src, off:off + cap]

    # stage 3: scatter build (sources = stage-2 recv ++ own send buffer)
    # + inner-axis all_to_all
    cat = np.concatenate([s2_recv, bufs], axis=1)
    s3_send = np.stack(
        [_gather(hs.s3_src[g], hs.s3_valid[g], cat[g]) for g in range(p)])
    s3_recv = np.zeros_like(s3_send)
    for o in range(p_outer):
        for q in range(p_inner):
            for sq in range(p_inner):
                s3_recv[o * p_inner + q, sq * hs.s3_cap:(sq + 1) * hs.s3_cap] = \
                    s3_send[o * p_inner + sq, q * hs.s3_cap:(q + 1) * hs.s3_cap]

    # final unpack into source-rank order
    return np.stack(
        [_gather(hs.unpack_src[g], hs.unpack_valid[g], s3_recv[g])
         for g in range(p)])


def _roundtrip(counts, p_outer, p_inner, feature=(3,), leader_perm=None):
    counts = np.asarray(counts, np.int64)
    p = counts.shape[0]
    send_rows = max(md.round_up(md.max_total_send(counts), 8), 8)
    recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
    bufs = reference.make_testbufs(counts, feature, np.float32, send_rows)
    want = reference.alltoallv_global(bufs, counts, recv_rows)
    got = simulate_two_stage(counts, p_outer, p_inner, bufs, recv_rows,
                             leader_perm=leader_perm)
    rc = md.recv_counts(counts)
    for r in range(p):
        n = int(rc[r].sum())
        np.testing.assert_array_equal(got[r, :n], want[r, :n], err_msg=f"rank {r}")


GROUP_SHAPES = [(2, 2), (2, 4), (4, 2), (2, 3), (3, 2), (4, 4), (1, 4), (4, 1)]


@pytest.mark.parametrize("p_outer,p_inner", GROUP_SHAPES)
def test_two_stage_roundtrip_dense(p_outer, p_inner):
    p = p_outer * p_inner
    rng = np.random.default_rng(p)
    _roundtrip(rng.integers(0, 13, (p, p)), p_outer, p_inner)


@pytest.mark.parametrize("p_outer,p_inner", [(2, 4), (4, 2)])
def test_two_stage_roundtrip_banded(p_outer, p_inner):
    p = p_outer * p_inner
    rng = np.random.default_rng(3)
    c = np.zeros((p, p), np.int64)
    for i in range(p):
        for d in (-1, 0, 1):
            c[i, (i + d) % p] = rng.integers(1, 9)
    _roundtrip(c, p_outer, p_inner)


@pytest.mark.parametrize("p_outer,p_inner", [(2, 4), (4, 2)])
def test_two_stage_roundtrip_skewed(p_outer, p_inner):
    p = p_outer * p_inner
    rng = np.random.default_rng(5)
    c = rng.integers(0, 4, (p, p))
    c[:, p - 1] *= 11          # hot receiver
    c[0, :] *= 7               # hot sender
    _roundtrip(c, p_outer, p_inner)


def test_two_stage_roundtrip_all_local():
    """Group-diagonal pattern: remote stages elide, schedule still correct."""
    p_outer, p_inner = 2, 4
    p = p_outer * p_inner
    rng = np.random.default_rng(7)
    c = np.zeros((p, p), np.int64)
    for g in range(p_outer):
        lo, hi = g * p_inner, (g + 1) * p_inner
        c[lo:hi, lo:hi] = rng.integers(0, 9, (p_inner, p_inner))
    hs = md.hier_two_stage_schedule(c, p_outer, p_inner, 64)
    assert not hs.remote_needed and hs.cross_group_puts == 0
    _roundtrip(c, p_outer, p_inner)


counts_and_shape = st.integers(0, 5).flatmap(
    lambda i: st.lists(
        st.lists(st.integers(0, 20),
                 min_size=GROUP_SHAPES[i][0] * GROUP_SHAPES[i][1],
                 max_size=GROUP_SHAPES[i][0] * GROUP_SHAPES[i][1]),
        min_size=GROUP_SHAPES[i][0] * GROUP_SHAPES[i][1],
        max_size=GROUP_SHAPES[i][0] * GROUP_SHAPES[i][1],
    ).map(lambda rows: (np.array(rows), GROUP_SHAPES[i])))


@given(counts_and_shape)
def test_two_stage_roundtrip_property(arg):
    counts, (p_outer, p_inner) = arg
    _roundtrip(counts, p_outer, p_inner)


# --- leader permutations (runtime.leader re-bakes) --------------------------

def _perm_for(seed, p_outer, p_inner):
    rng = np.random.default_rng(seed)
    return tuple(tuple(int(x) for x in rng.permutation(p_inner))
                 for _ in range(p_outer))


counts_shape_and_perm = counts_and_shape.flatmap(
    lambda cs_: st.integers(0, 2**16).map(
        lambda seed: (cs_[0], cs_[1], _perm_for(seed, *cs_[1]))))


@given(counts_shape_and_perm)
def test_two_stage_roundtrip_any_leader_perm(arg):
    """Oracle parity holds for EVERY per-group leader permutation — a
    re-bake can never change the exchange's result."""
    counts, (p_outer, p_inner), perm = arg
    _roundtrip(counts, p_outer, p_inner, leader_perm=perm)


@given(counts_shape_and_perm)
def test_leader_perm_invariants(arg):
    """Leadership re-assignment moves WHO carries, never WHAT is carried:
    cross_group_puts, slab capacities, and buffer geometry are pure
    functions of the traffic pattern, invariant under the permutation."""
    counts, (p_outer, p_inner), perm = arg
    counts = np.asarray(counts, np.int64)
    recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
    base = md.hier_two_stage_schedule(counts, p_outer, p_inner, recv_rows)
    got = md.hier_two_stage_schedule(counts, p_outer, p_inner, recv_rows,
                                     leader_perm=perm)
    assert got.cross_group_puts == base.cross_group_puts
    assert got.s2_caps == base.s2_caps
    # s1 buckets hold (member -> leader ROLE) rows, so the max over pairs
    # is assignment-invariant.  s3 buckets mix a role's scatter rows with
    # the physical rank's own local-bypass rows, so s3_cap may legitimately
    # change with the pairing — geometry, not pattern identity.
    assert got.s1_cap == base.s1_cap
    assert got.remote_needed == base.remote_needed
    assert got.leader_perm == md.normalize_leader_perm(perm, p_outer, p_inner)


@given(counts_shape_and_perm)
def test_leader_perm_slabs_carried_exactly_once(arg):
    """Every active group pair's slab crosses the inter-group hop exactly
    once per epoch, by exactly one (leader, leader) put — under any
    permutation.  The carriers are the permuted leaders of their groups."""
    counts, (p_outer, p_inner), perm = arg
    counts = np.asarray(counts, np.int64)
    recv_rows = max(md.round_up(md.max_total_recv(counts), 8), 8)
    hs = md.hier_two_stage_schedule(counts, p_outer, p_inner, recv_rows,
                                    leader_perm=perm)
    grp = np.arange(p_outer * p_inner) // p_inner
    cross = np.zeros((p_outer, p_outer), np.int64)
    for so in range(p_outer):
        for to in range(p_outer):
            if so != to:
                cross[so, to] = counts[np.ix_(grp == so, grp == to)].sum()
    pairs = [(src, dst) for rnd in hs.round_perms for (src, dst) in rnd]
    group_pairs = [(s // p_inner, d // p_inner) for s, d in pairs]
    # once each, and exactly the active pairs
    assert len(group_pairs) == len(set(group_pairs))
    assert set(group_pairs) == {(so, to) for so in range(p_outer)
                                for to in range(p_outer)
                                if so != to and cross[so, to] > 0}
    # each put runs between the groups' elected leaders for that round
    norm = md.normalize_leader_perm(perm, p_outer, p_inner)
    for m, rnd in enumerate(hs.round_perms):
        for src, dst in rnd:
            so, to = src // p_inner, dst // p_inner
            q_src = src % p_inner
            # the sending leader's role q satisfies perm[so][q] == q_src,
            # and the receiving side uses the SAME role in its own group
            role = norm[so].index(q_src)
            assert norm[to][role] == dst % p_inner


def test_identity_leader_perm_matches_default():
    """identity perm bakes byte-identical tables to the perm-free call —
    the digest-stability guarantee the plan-store keying relies on."""
    p_outer, p_inner = 2, 4
    p = p_outer * p_inner
    rng = np.random.default_rng(11)
    c = rng.integers(0, 9, (p, p))
    recv_rows = max(md.round_up(md.max_total_recv(c), 8), 8)
    a = md.hier_two_stage_schedule(c, p_outer, p_inner, recv_rows)
    b = md.hier_two_stage_schedule(
        c, p_outer, p_inner, recv_rows,
        leader_perm=md.identity_leader_perm(p_outer, p_inner))
    assert a.round_perms == b.round_perms
    for fld in ("s1_src", "s1_valid", "s2_src", "s2_valid",
                "s3_src", "s3_valid", "unpack_src", "unpack_valid"):
        np.testing.assert_array_equal(getattr(a, fld), getattr(b, fld))


def test_cross_group_put_count_scaling():
    """Dense pattern: combined put count is exactly P_outer*(P_outer-1) —
    O((P/g)^2) — versus P*(P-1) for the flat fence epoch."""
    for p_outer, p_inner in [(2, 4), (4, 2), (4, 4)]:
        p = p_outer * p_inner
        c = np.full((p, p), 3, np.int64)
        hs = md.hier_two_stage_schedule(c, p_outer, p_inner, 8 * p)
        assert hs.cross_group_puts == p_outer * (p_outer - 1)
        assert hs.cross_group_puts < p * (p - 1)


def test_sparse_slabs_drop_from_perms():
    """Only group pairs that actually exchange rows appear in the round
    permutations; empty macro-rounds are elided (capacity 0)."""
    p_outer, p_inner = 4, 2
    p = p_outer * p_inner
    c = np.zeros((p, p), np.int64)
    c[0, p_inner] = 5          # group 0 -> group 1 only
    hs = md.hier_two_stage_schedule(c, p_outer, p_inner, 8)
    assert hs.cross_group_puts == 1
    active = [m for m, cap in enumerate(hs.s2_caps) if cap > 0]
    assert len(active) == 1
    (src, dst), = hs.round_perms[active[0]]
    assert src // p_inner == 0 and dst // p_inner == 1
