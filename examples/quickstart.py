"""Quickstart: train a small LM for a few steps and watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import ShapeConfig, get_reduced
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.train import ScheduleConfig, Trainer, TrainerConfig


def main():
    cfg = get_reduced("olmo-1b")                      # 4L/128d smoke config
    shape = ShapeConfig("quickstart", "train", seq_len=128, global_batch=8)
    mesh = make_mesh((1, 1), ("data", "model"))       # single device

    bundle = steps_mod.make_train_bundle(
        cfg, shape, mesh,
        sched=ScheduleConfig(kind="cosine", peak_lr=3e-3, warmup_steps=5,
                             total_steps=50))
    trainer = Trainer(bundle, TrainerConfig(n_steps=50, log_every=10))
    result = trainer.run()

    first = trainer.history[0]["nll"]
    last = trainer.history[-1]["nll"]
    print(f"\nnll {first:.3f} -> {last:.3f} over {result['final_step']} steps")
    assert last < first


if __name__ == "__main__":
    main()
