"""The paper in miniature: persistent RMA-style alltoallv on 8 ranks.

Builds an irregular (hugetrace-like) communication pattern, runs the
non-persistent baseline and the persistent fence / lock / hierarchy plans,
validates every byte against the numpy oracle, and prints the break-even
analysis (paper Eq. 1-3).

    PYTHONPATH=src python examples/persistent_alltoallv.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import alltoallv_init, breakeven, metadata as md, reference
from repro.core.baseline import make_nonpersistent
from repro.launch.mesh import make_host_mesh, make_mesh


def main():
    p, feature = 8, 128
    rng = np.random.default_rng(7)
    counts = rng.integers(0, 64, size=(p, p))
    counts[:, 5] *= 4                      # one hot receiver (skew)
    print("count matrix (rows=senders):")
    print(counts)

    send_rows = md.round_up(md.max_total_send(counts), 8)
    recv_rows = md.round_up(md.max_total_recv(counts), 8)
    bufs = reference.make_testbufs(counts, (feature,), np.float32, send_rows)
    expect = reference.alltoallv_global(bufs, counts, recv_rows)
    rc = md.recv_counts(counts)

    mesh = make_host_mesh(p)
    x = jax.device_put(jnp.asarray(bufs.reshape(p * send_rows, feature)),
                       NamedSharding(mesh, P("x")))

    def validate(out, label):
        got = np.asarray(out).reshape(p, recv_rows, feature)
        for r in range(p):
            n = int(rc[r].sum())
            np.testing.assert_allclose(got[r, :n], expect[r, :n], rtol=1e-6)
        print(f"  {label:24s} validated element-wise")

    # ---- INIT (one-time) + START/WAIT (per-iteration) ----
    plans = {}
    for variant in ("fence", "lock"):
        t0 = time.perf_counter()
        plan = alltoallv_init(counts, (feature,), jnp.float32, mesh,
                              axis="x", variant=variant)
        plan.compile()
        print(f"INIT {variant}: host metadata {plan.init_host_seconds*1e6:.0f} us, "
              f"compile {plan.init_compile_seconds:.2f} s")
        validate(plan.wait(plan.start(x)), f"{variant}_persistent")
        plans[variant] = plan

    mesh2 = make_mesh((2, 4), ("node", "core"))
    x2 = jax.device_put(jnp.asarray(bufs.reshape(p * send_rows, feature)),
                        NamedSharding(mesh2, P(("node", "core"))))
    plan_h = alltoallv_init(counts, (feature,), jnp.float32, mesh2,
                            axis=("node", "core"), variant="fence_hierarchy")
    validate(plan_h.wait(plan_h.start(x2)), "fence_hierarchy")

    base = make_nonpersistent(mesh, axis="x", p=p,
                              capacity=plans["fence"].capacity,
                              send_rows=send_rows, recv_rows=recv_rows,
                              feature_shape=(feature,), dtype=jnp.float32)
    cnts = jax.device_put(jnp.asarray(counts.reshape(-1), jnp.int32),
                          NamedSharding(mesh, P("x")))
    validate(base(x, cnts), "nonpersistent baseline")

    # ---- break-even (Eq. 1-3) ----
    print("\nbreak-even analysis:")
    for variant, plan in plans.items():
        be = breakeven.measure(lambda: plan.start(x), lambda: base(x, cnts),
                               t_init=plan.init_host_seconds, iters=30)
        print(f"  {variant:6s}: T_MPI={be.t_mpi*1e6:8.1f} us  "
              f"T_persist={be.t_persist*1e6:8.1f} us  "
              f"savings={be.savings_pct:5.1f}%  N_breakeven={be.n_breakeven}")


if __name__ == "__main__":
    main()
