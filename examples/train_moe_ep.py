"""End-to-end driver: train a reduced OLMoE with expert-parallel MoE
dispatch running through the persistent alltoallv engine, on a
(data=2, model=4) mesh of host devices, with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_moe_ep.py [n_steps]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

from repro.configs import ShapeConfig, get_reduced
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh
from repro.train import ScheduleConfig, Trainer, TrainerConfig


def main(n_steps: int = 60):
    cfg = get_reduced("olmoe-1b-7b")       # 8 experts, top-2, persistent a2a
    shape = ShapeConfig("moe_ep", "train", seq_len=256, global_batch=8)
    mesh = make_mesh((2, 4), ("data", "model"))   # DP=2, TP/EP=4

    bundle = steps_mod.make_train_bundle(
        cfg, shape, mesh,
        sched=ScheduleConfig(kind="wsd", peak_lr=3e-3, warmup_steps=6,
                             total_steps=n_steps, decay_steps=n_steps // 5))
    plan = bundle.meta["moe_plan"]
    print(f"MoE dispatch plan: EP={plan.ep_size}, {plan.e_local} experts/shard, "
          f"capacity={plan.capacity}, variant={plan.variant}, "
          f"plan_backed={plan.plan_backed}"
          + (f" (warm={plan.a2a.warm_loaded})" if plan.plan_backed else ""))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(bundle, TrainerConfig(
            n_steps=n_steps, ckpt_dir=ckpt_dir, ckpt_every=20, log_every=10))
        result = trainer.run()
        print(f"\nfinished at step {result['final_step']}; "
              f"last: {result['last_metrics']}")
        first = trainer.history[0]["nll"]
        last = trainer.history[-1]["nll"]
        print(f"nll {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 60)
