"""Batched serving: prefill a batch of prompts, decode with persistent KV
caches (donated buffers = window reuse), report throughput.

    PYTHONPATH=src python examples/serve_batched.py
"""

import numpy as np

from repro.configs import get_reduced
from repro.launch.mesh import make_mesh
from repro.serve import ServeEngine


def main():
    cfg = get_reduced("minicpm-2b")
    mesh = make_mesh((1, 1), ("data", "model"))
    batch, prompt_len, n_tokens = 4, 32, 16

    engine = ServeEngine(cfg, mesh, batch=batch, prompt_len=prompt_len,
                         max_seq=prompt_len + n_tokens + 8, seed=0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)

    tokens, stats = engine.generate(prompts, n_tokens)
    print(f"prompts {prompts.shape} -> generated {tokens.shape}")
    print(f"prefill: {stats.prefill_seconds*1e3:.1f} ms")
    print(f"decode:  {stats.decode_seconds_per_token*1e3:.2f} ms/token "
          f"({batch / max(stats.decode_seconds_per_token, 1e-9):.1f} tok/s batched)")
    print("first sequences:", tokens[:2].tolist())


if __name__ == "__main__":
    main()
